//! `cblas_*` exports: the drop-in blocking surface.
//!
//! Each entry point maps the CBLAS integer enums, folds row-major
//! calls onto the column-major engine with the standard operand/flag
//! swaps (row-major X *is* column-major X^T, so GEMM swaps A/B and
//! their transposes, the symmetric/triangular routines flip
//! `side`/`uplo` and swap M/N), validates pointers, and executes the
//! planned call on the process-global default context — i.e. through
//! the resident multi-tenant runtime. Errors follow CBLAS convention:
//! an xerbla-style line on stderr, the call returns without computing
//! (`blasx_last_error` retrieves the message).
//!
//! Operands are wrapped through [`super::raw_operand`], **not** Rust
//! slices: the C ABI advertises that a blocking call may alias an
//! in-flight async job's buffers (the admission table orders the
//! accesses), so conjuring a `&mut [T]` over the output here — live
//! across the submit-and-wait while workers of an ordered-before job
//! still write the range — would be undefined behavior even though
//! the bytes never race.
//!
//! Panics are contained at the ABI boundary: unwinding across
//! `extern "C"` is undefined behavior, so every entry runs under
//! `catch_unwind` and reports instead.

use super::{
    default_context, diag_of, dim_of, fold_gemm_row_major, fold_sided_row_major,
    fold_syrk_row_major, order_of, raw_operand, record_error, side_of, trans_of, uplo_of, Order,
};
use crate::api::l3::{plan_gemm, plan_symm, plan_syr2k, plan_syrk, plan_trmm, plan_trsm};
use crate::api::types::{Diag, Scalar, Side, Trans, Uplo};
use crate::coordinator::real_engine::Mats;
use crate::error::{illegal, Error, Result};
use crate::tile::MatId;
use core::ffi::c_int;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Run `f` with panics contained and errors reported CBLAS-style.
fn entry(routine: &'static str, f: impl FnOnce() -> Result<()>) {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(Ok(())) => {}
        Ok(Err(e)) => record_error(routine, &e),
        Err(_) => record_error(routine, &Error::Internal("panic contained at the C ABI".into())),
    }
}

// --- GEMM ------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn gemm_entry<T: Scalar>(
    routine: &'static str,
    order: c_int,
    transa: c_int,
    transb: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: T,
    a: *const T,
    lda: c_int,
    b: *const T,
    ldb: c_int,
    beta: T,
    c: *mut T,
    ldc: c_int,
) {
    entry(routine, || {
        let order = order_of(order).ok_or_else(|| illegal(routine, 1, "bad order"))?;
        let mut ta = trans_of(transa).ok_or_else(|| illegal(routine, 2, "bad transA"))?;
        let mut tb = trans_of(transb).ok_or_else(|| illegal(routine, 3, "bad transB"))?;
        let mut m = dim_of(m).ok_or_else(|| illegal(routine, 4, "m < 0"))?;
        let mut n = dim_of(n).ok_or_else(|| illegal(routine, 5, "n < 0"))?;
        let k = dim_of(k).ok_or_else(|| illegal(routine, 6, "k < 0"))?;
        let mut lda = dim_of(lda).ok_or_else(|| illegal(routine, 9, "lda < 0"))?;
        let mut ldb = dim_of(ldb).ok_or_else(|| illegal(routine, 11, "ldb < 0"))?;
        let ldc = dim_of(ldc).ok_or_else(|| illegal(routine, 14, "ldc < 0"))?;
        let (mut a, mut b) = (a, b);
        if order == Order::RowMajor {
            fold_gemm_row_major(&mut ta, &mut tb, &mut m, &mut n, &mut lda, &mut ldb, &mut a, &mut b);
        }
        if m == 0 || n == 0 {
            return Ok(());
        }
        let ctx = default_context();
        let t = ctx.tile();
        let (ts, dims) =
            plan_gemm(t, ta, tb, m, n, k, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
        let (ar, ac) = dims.a;
        let (br, bc) = dims.b.expect("gemm has a B operand");
        // SAFETY: BLAS buffer contract (footprint per ld/dims), held
        // for the duration of this blocking call.
        let (am, bm, cm) = unsafe {
            (
                raw_operand(routine, 8, a as *mut T, ar, ac, lda, t, MatId::A)?,
                raw_operand(routine, 10, b as *mut T, br, bc, ldb, t, MatId::B)?,
                raw_operand(routine, 13, c, m, n, ldc, t, MatId::C)?,
            )
        };
        ctx.execute(routine, &ts, vec![Mats { a: &am, b: Some(&bm), c: &cm }]).map(|_| ())
    })
}

/// `C := alpha*op(A)*op(B) + beta*C`, double precision (CBLAS ABI).
///
/// # Safety
/// Standard BLAS buffer contract: every non-null pointer must cover
/// the column-/row-major footprint implied by its dimensions and
/// leading dimension for the duration of the call, and the output
/// must not overlap the inputs.
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dgemm(
    order: c_int,
    transa: c_int,
    transb: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: f64,
    a: *const f64,
    lda: c_int,
    b: *const f64,
    ldb: c_int,
    beta: f64,
    c: *mut f64,
    ldc: c_int,
) {
    gemm_entry("cblas_dgemm", order, transa, transb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// Single-precision GEMM (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_sgemm(
    order: c_int,
    transa: c_int,
    transb: c_int,
    m: c_int,
    n: c_int,
    k: c_int,
    alpha: f32,
    a: *const f32,
    lda: c_int,
    b: *const f32,
    ldb: c_int,
    beta: f32,
    c: *mut f32,
    ldc: c_int,
) {
    gemm_entry("cblas_sgemm", order, transa, transb, m, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

// --- SYRK / SYR2K ----------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn syrk_entry<T: Scalar>(
    routine: &'static str,
    order: c_int,
    uplo: c_int,
    trans: c_int,
    n: c_int,
    k: c_int,
    alpha: T,
    a: *const T,
    lda: c_int,
    beta: T,
    c: *mut T,
    ldc: c_int,
) {
    entry(routine, || {
        let order = order_of(order).ok_or_else(|| illegal(routine, 1, "bad order"))?;
        let mut uplo = uplo_of(uplo).ok_or_else(|| illegal(routine, 2, "bad uplo"))?;
        let mut trans = trans_of(trans).ok_or_else(|| illegal(routine, 3, "bad trans"))?;
        let n = dim_of(n).ok_or_else(|| illegal(routine, 4, "n < 0"))?;
        let k = dim_of(k).ok_or_else(|| illegal(routine, 5, "k < 0"))?;
        let lda = dim_of(lda).ok_or_else(|| illegal(routine, 8, "lda < 0"))?;
        let ldc = dim_of(ldc).ok_or_else(|| illegal(routine, 11, "ldc < 0"))?;
        if order == Order::RowMajor {
            fold_syrk_row_major(&mut uplo, &mut trans);
        }
        if n == 0 {
            return Ok(());
        }
        let ctx = default_context();
        let t = ctx.tile();
        let (ts, dims) =
            plan_syrk(t, uplo, trans, n, k, alpha.to_f64(), beta.to_f64(), lda, ldc)?;
        let (ar, ac) = dims.a;
        // SAFETY: BLAS buffer contract.
        let (am, cm) = unsafe {
            (
                raw_operand(routine, 7, a as *mut T, ar, ac, lda, t, MatId::A)?,
                raw_operand(routine, 10, c, n, n, ldc, t, MatId::C)?,
            )
        };
        ctx.execute(routine, &ts, vec![Mats { a: &am, b: None, c: &cm }]).map(|_| ())
    })
}

/// `C := alpha*op(A)*op(A)^T + beta*C`, double precision (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dsyrk(
    order: c_int,
    uplo: c_int,
    trans: c_int,
    n: c_int,
    k: c_int,
    alpha: f64,
    a: *const f64,
    lda: c_int,
    beta: f64,
    c: *mut f64,
    ldc: c_int,
) {
    syrk_entry("cblas_dsyrk", order, uplo, trans, n, k, alpha, a, lda, beta, c, ldc)
}

/// Single-precision SYRK (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_ssyrk(
    order: c_int,
    uplo: c_int,
    trans: c_int,
    n: c_int,
    k: c_int,
    alpha: f32,
    a: *const f32,
    lda: c_int,
    beta: f32,
    c: *mut f32,
    ldc: c_int,
) {
    syrk_entry("cblas_ssyrk", order, uplo, trans, n, k, alpha, a, lda, beta, c, ldc)
}

#[allow(clippy::too_many_arguments)]
fn syr2k_entry<T: Scalar>(
    routine: &'static str,
    order: c_int,
    uplo: c_int,
    trans: c_int,
    n: c_int,
    k: c_int,
    alpha: T,
    a: *const T,
    lda: c_int,
    b: *const T,
    ldb: c_int,
    beta: T,
    c: *mut T,
    ldc: c_int,
) {
    entry(routine, || {
        let order = order_of(order).ok_or_else(|| illegal(routine, 1, "bad order"))?;
        let mut uplo = uplo_of(uplo).ok_or_else(|| illegal(routine, 2, "bad uplo"))?;
        let mut trans = trans_of(trans).ok_or_else(|| illegal(routine, 3, "bad trans"))?;
        let n = dim_of(n).ok_or_else(|| illegal(routine, 4, "n < 0"))?;
        let k = dim_of(k).ok_or_else(|| illegal(routine, 5, "k < 0"))?;
        let lda = dim_of(lda).ok_or_else(|| illegal(routine, 8, "lda < 0"))?;
        let ldb = dim_of(ldb).ok_or_else(|| illegal(routine, 10, "ldb < 0"))?;
        let ldc = dim_of(ldc).ok_or_else(|| illegal(routine, 13, "ldc < 0"))?;
        if order == Order::RowMajor {
            fold_syrk_row_major(&mut uplo, &mut trans);
        }
        if n == 0 {
            return Ok(());
        }
        let ctx = default_context();
        let t = ctx.tile();
        let (ts, dims) =
            plan_syr2k(t, uplo, trans, n, k, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
        let (ar, ac) = dims.a;
        // SAFETY: BLAS buffer contract.
        let (am, bm, cm) = unsafe {
            (
                raw_operand(routine, 7, a as *mut T, ar, ac, lda, t, MatId::A)?,
                raw_operand(routine, 9, b as *mut T, ar, ac, ldb, t, MatId::B)?,
                raw_operand(routine, 12, c, n, n, ldc, t, MatId::C)?,
            )
        };
        ctx.execute(routine, &ts, vec![Mats { a: &am, b: Some(&bm), c: &cm }]).map(|_| ())
    })
}

/// `C := alpha*(op(A)op(B)^T + op(B)op(A)^T) + beta*C`, double
/// precision (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dsyr2k(
    order: c_int,
    uplo: c_int,
    trans: c_int,
    n: c_int,
    k: c_int,
    alpha: f64,
    a: *const f64,
    lda: c_int,
    b: *const f64,
    ldb: c_int,
    beta: f64,
    c: *mut f64,
    ldc: c_int,
) {
    syr2k_entry("cblas_dsyr2k", order, uplo, trans, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// Single-precision SYR2K (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_ssyr2k(
    order: c_int,
    uplo: c_int,
    trans: c_int,
    n: c_int,
    k: c_int,
    alpha: f32,
    a: *const f32,
    lda: c_int,
    b: *const f32,
    ldb: c_int,
    beta: f32,
    c: *mut f32,
    ldc: c_int,
) {
    syr2k_entry("cblas_ssyr2k", order, uplo, trans, n, k, alpha, a, lda, b, ldb, beta, c, ldc)
}

// --- SYMM ------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn symm_entry<T: Scalar>(
    routine: &'static str,
    order: c_int,
    side: c_int,
    uplo: c_int,
    m: c_int,
    n: c_int,
    alpha: T,
    a: *const T,
    lda: c_int,
    b: *const T,
    ldb: c_int,
    beta: T,
    c: *mut T,
    ldc: c_int,
) {
    entry(routine, || {
        let order = order_of(order).ok_or_else(|| illegal(routine, 1, "bad order"))?;
        let mut side = side_of(side).ok_or_else(|| illegal(routine, 2, "bad side"))?;
        let mut uplo = uplo_of(uplo).ok_or_else(|| illegal(routine, 3, "bad uplo"))?;
        let mut m = dim_of(m).ok_or_else(|| illegal(routine, 4, "m < 0"))?;
        let mut n = dim_of(n).ok_or_else(|| illegal(routine, 5, "n < 0"))?;
        let lda = dim_of(lda).ok_or_else(|| illegal(routine, 8, "lda < 0"))?;
        let ldb = dim_of(ldb).ok_or_else(|| illegal(routine, 10, "ldb < 0"))?;
        let ldc = dim_of(ldc).ok_or_else(|| illegal(routine, 13, "ldc < 0"))?;
        if order == Order::RowMajor {
            fold_sided_row_major(&mut side, &mut uplo, &mut m, &mut n);
        }
        if m == 0 || n == 0 {
            return Ok(());
        }
        let ctx = default_context();
        let t = ctx.tile();
        let (ts, dims) =
            plan_symm(t, side, uplo, m, n, alpha.to_f64(), beta.to_f64(), lda, ldb, ldc)?;
        let (na, _) = dims.a;
        // SAFETY: BLAS buffer contract.
        let (am, bm, cm) = unsafe {
            (
                raw_operand(routine, 7, a as *mut T, na, na, lda, t, MatId::A)?,
                raw_operand(routine, 9, b as *mut T, m, n, ldb, t, MatId::B)?,
                raw_operand(routine, 12, c, m, n, ldc, t, MatId::C)?,
            )
        };
        ctx.execute(routine, &ts, vec![Mats { a: &am, b: Some(&bm), c: &cm }]).map(|_| ())
    })
}

/// `C := alpha*sym(A)*B + beta*C` (Left) / `alpha*B*sym(A) + beta*C`
/// (Right), double precision (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dsymm(
    order: c_int,
    side: c_int,
    uplo: c_int,
    m: c_int,
    n: c_int,
    alpha: f64,
    a: *const f64,
    lda: c_int,
    b: *const f64,
    ldb: c_int,
    beta: f64,
    c: *mut f64,
    ldc: c_int,
) {
    symm_entry("cblas_dsymm", order, side, uplo, m, n, alpha, a, lda, b, ldb, beta, c, ldc)
}

/// Single-precision SYMM (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_ssymm(
    order: c_int,
    side: c_int,
    uplo: c_int,
    m: c_int,
    n: c_int,
    alpha: f32,
    a: *const f32,
    lda: c_int,
    b: *const f32,
    ldb: c_int,
    beta: f32,
    c: *mut f32,
    ldc: c_int,
) {
    symm_entry("cblas_ssymm", order, side, uplo, m, n, alpha, a, lda, b, ldb, beta, c, ldc)
}

// --- TRMM / TRSM -----------------------------------------------------

/// Shared parse + row-major fold for the two in-place triangular
/// routines; returns the column-major arguments or `None` on quick
/// return.
type TriArgs = (Side, Uplo, Trans, Diag, usize, usize, usize, usize);

#[allow(clippy::too_many_arguments)]
fn trxm_args(
    routine: &'static str,
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    lda: c_int,
    ldb: c_int,
) -> Result<Option<TriArgs>> {
    let order = order_of(order).ok_or_else(|| illegal(routine, 1, "bad order"))?;
    let mut side = side_of(side).ok_or_else(|| illegal(routine, 2, "bad side"))?;
    let mut uplo = uplo_of(uplo).ok_or_else(|| illegal(routine, 3, "bad uplo"))?;
    let ta = trans_of(transa).ok_or_else(|| illegal(routine, 4, "bad transA"))?;
    let diag = diag_of(diag).ok_or_else(|| illegal(routine, 5, "bad diag"))?;
    let mut m = dim_of(m).ok_or_else(|| illegal(routine, 6, "m < 0"))?;
    let mut n = dim_of(n).ok_or_else(|| illegal(routine, 7, "n < 0"))?;
    let lda = dim_of(lda).ok_or_else(|| illegal(routine, 10, "lda < 0"))?;
    let ldb = dim_of(ldb).ok_or_else(|| illegal(routine, 12, "ldb < 0"))?;
    if order == Order::RowMajor {
        fold_sided_row_major(&mut side, &mut uplo, &mut m, &mut n);
    }
    if m == 0 || n == 0 {
        return Ok(None);
    }
    Ok(Some((side, uplo, ta, diag, m, n, lda, ldb)))
}

/// TRMM/TRSM shared executor over the planned task set.
fn trxm_run<T: Scalar>(
    routine: &'static str,
    is_trsm: bool,
    args: TriArgs,
    alpha: T,
    a: *const T,
    b: *mut T,
) -> Result<()> {
    let (side, uplo, ta, diag, m, n, lda, ldb) = args;
    let ctx = default_context();
    let t = ctx.tile();
    let plan = if is_trsm { plan_trsm } else { plan_trmm };
    let (ts, dims) = plan(t, side, uplo, ta, diag, m, n, alpha.to_f64(), lda, ldb)?;
    let (na, _) = dims.a;
    // SAFETY: BLAS buffer contract.
    let (am, cm) = unsafe {
        (
            raw_operand(routine, 9, a as *mut T, na, na, lda, t, MatId::A)?,
            raw_operand(routine, 11, b, m, n, ldb, t, MatId::C)?,
        )
    };
    ctx.execute(routine, &ts, vec![Mats { a: &am, b: None, c: &cm }]).map(|_| ())
}

#[allow(clippy::too_many_arguments)]
fn trmm_entry<T: Scalar>(
    routine: &'static str,
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    alpha: T,
    a: *const T,
    lda: c_int,
    b: *mut T,
    ldb: c_int,
) {
    entry(routine, || {
        match trxm_args(routine, order, side, uplo, transa, diag, m, n, lda, ldb)? {
            Some(args) => trxm_run(routine, false, args, alpha, a, b),
            None => Ok(()),
        }
    })
}

#[allow(clippy::too_many_arguments)]
fn trsm_entry<T: Scalar>(
    routine: &'static str,
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    alpha: T,
    a: *const T,
    lda: c_int,
    b: *mut T,
    ldb: c_int,
) {
    entry(routine, || {
        match trxm_args(routine, order, side, uplo, transa, diag, m, n, lda, ldb)? {
            Some(args) => trxm_run(routine, true, args, alpha, a, b),
            None => Ok(()),
        }
    })
}

/// `B := alpha*op(tri(A))*B` (Left) / `alpha*B*op(tri(A))` (Right), in
/// place, double precision (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dtrmm(
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    alpha: f64,
    a: *const f64,
    lda: c_int,
    b: *mut f64,
    ldb: c_int,
) {
    trmm_entry("cblas_dtrmm", order, side, uplo, transa, diag, m, n, alpha, a, lda, b, ldb)
}

/// Single-precision TRMM (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_strmm(
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    alpha: f32,
    a: *const f32,
    lda: c_int,
    b: *mut f32,
    ldb: c_int,
) {
    trmm_entry("cblas_strmm", order, side, uplo, transa, diag, m, n, alpha, a, lda, b, ldb)
}

/// Solve `op(tri(A))*X = alpha*B` (Left) / `X*op(tri(A)) = alpha*B`
/// (Right), X overwriting B, double precision (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_dtrsm(
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    alpha: f64,
    a: *const f64,
    lda: c_int,
    b: *mut f64,
    ldb: c_int,
) {
    trsm_entry("cblas_dtrsm", order, side, uplo, transa, diag, m, n, alpha, a, lda, b, ldb)
}

/// Single-precision TRSM (CBLAS ABI).
///
/// # Safety
/// As [`cblas_dgemm`].
#[no_mangle]
#[allow(clippy::too_many_arguments)]
pub unsafe extern "C" fn cblas_strsm(
    order: c_int,
    side: c_int,
    uplo: c_int,
    transa: c_int,
    diag: c_int,
    m: c_int,
    n: c_int,
    alpha: f32,
    a: *const f32,
    lda: c_int,
    b: *mut f32,
    ldb: c_int,
) {
    trsm_entry("cblas_strsm", order, side, uplo, transa, diag, m, n, alpha, a, lda, b, ldb)
}
