//! Batched L3 BLAS subsystem — many small/irregular problems through
//! one scheduler invocation.
//!
//! The per-call runtime (taskize → queue → reservation stations →
//! tile caches → kernels) was built for one large problem whose tile
//! grid dwarfs the device set. Serving-style workloads are the opposite
//! regime: hundreds of problems, each with a handful of tiles — too
//! small to fill even one device's streams, so looping single calls
//! leaves most of the machine idle and pays taskization, cache warm-up
//! and stream setup per problem (the motivation behind KBLAS's batched
//! routines and Stream-K's work-centric decomposition).
//!
//! This module turns the existing runtime into a throughput engine in
//! three steps, none of which touch the scheduling policy itself:
//!
//! 1. **Descriptors** ([`desc`]): [`BatchedGemm`] / [`BatchedSyrk`] /
//!    [`BatchedTrsm`] hold per-problem routine descriptors (uniform
//!    batches are just `vec![proto; count]`), wrapped in [`BatchDesc`].
//! 2. **Fusion** ([`fuse`]): every problem is taskized with the
//!    existing per-routine taskizers, then fused into ONE `TaskSet` —
//!    ids renumbered, dependency chains offset, and every task/tile
//!    reference tagged with its *problem index* `p`. The `KeyMap` and
//!    the real engine resolve `(p, mat, ti, tj)` to per-problem
//!    operands, so the ALRU cache and MESI-X coherence layers work
//!    unchanged across problems: the batch is just a bigger key space.
//! 3. **Work-centric quanta** ([`quanta`]): the fused ready set is
//!    emitted in *scheduling quanta* — flop-balanced groups that
//!    interleave problems round-robin — so the demand-driven queue
//!    hands every device useful work from the first round and the
//!    work-stealing stations stay saturated even when individual
//!    problems have fewer tiles than the machine has streams.
//!
//! Public entry points live in [`crate::api::l3`]
//! (`{s,d}gemm_batched`, strided and pointer-array variants); the
//! simulator path is [`crate::coordinator::dispatch::gemm_batch_workload`].

pub mod desc;
pub mod fuse;
pub mod quanta;

pub use desc::{BatchDesc, BatchedGemm, BatchedSyrk, BatchedTrsm};
pub use fuse::{fuse_batch, taskize_batch};
pub use quanta::{plan_quanta, QuantaPlan, Quantum};
