//! Work-centric scheduling quanta (the Stream-K idea applied to the
//! fused ready set).
//!
//! Per-problem tiling hands the scheduler work in problem-sized lumps:
//! if problem 0 has 4 tile tasks and the machine has 4 devices × 4
//! streams, emitting problem 0's tasks before problem 1's leaves 12
//! stream slots dry until the queue reaches the next problem, and the
//! demand-driven refill (one stream-round budget per wake) amplifies
//! the effect. The splitter instead treats the whole batch as one flat
//! pool of work and carves it into *quanta* — groups of head tasks
//! with roughly equal flops, filled round-robin across problems — and
//! the fused `TaskSet` emits its ready set in quantum order. Devices
//! then pull balanced, problem-diverse work from the first wake
//! onward, and the work-stealing stations have meaningfully sized
//! victims from the start.
//!
//! Only *heads* are planned: chained tasks (TRSM) enter the queue
//! dynamically when their predecessor completes, so a head's cost is
//! accounted as its whole chain (the chain is sequential work pinned
//! behind that head).

use crate::task::Task;

/// One scheduling quantum: a group of head tasks emitted contiguously.
#[derive(Clone, Debug)]
pub struct Quantum {
    /// Head task ids (into the fused task vector).
    pub tasks: Vec<usize>,
    /// Aggregate chain flops of those heads.
    pub flops: f64,
}

/// The splitter's output: the fused emission order plus the quantum
/// structure (kept for observability and tests).
#[derive(Clone, Debug)]
pub struct QuantaPlan {
    /// All head ids in emission order (quanta concatenated).
    pub order: Vec<usize>,
    pub quanta: Vec<Quantum>,
    /// Flop target per quantum the splitter aimed for.
    pub target_flops: f64,
}

/// Quanta per worker the splitter aims for. Mirrors the stream count:
/// each device can have `n_streams` tasks in flight plus a staged RS,
/// so ~4 quanta per worker keeps refills non-empty without shredding
/// locality into single-task quanta.
const QUANTA_PER_WORKER: usize = 4;

/// Total flops of the chain starting at head `h` (the head itself for
/// independent tasks).
fn chain_flops(tasks: &[Task], h: usize) -> f64 {
    let mut f = 0.0;
    let mut cur = Some(h);
    while let Some(i) = cur {
        f += tasks[i].flops;
        cur = tasks[i].successor;
    }
    f
}

/// Carve the fused ready set into flop-balanced, problem-interleaved
/// quanta. `heads_per_problem[p]` lists problem `p`'s initially-ready
/// task ids (in that problem's natural emission order).
pub fn plan_quanta(
    tasks: &[Task],
    heads_per_problem: &[Vec<usize>],
    n_workers: usize,
) -> QuantaPlan {
    let n_heads: usize = heads_per_problem.iter().map(Vec::len).sum();
    let total: f64 = heads_per_problem
        .iter()
        .flatten()
        .map(|&h| chain_flops(tasks, h))
        .sum();
    let n_quanta = (n_workers.max(1) * QUANTA_PER_WORKER).min(n_heads.max(1));
    let target = (total / n_quanta as f64).max(1.0);

    let mut order = Vec::with_capacity(n_heads);
    let mut quanta = Vec::new();
    let mut cur = Quantum { tasks: Vec::new(), flops: 0.0 };
    let mut cursors = vec![0usize; heads_per_problem.len()];
    let mut remaining = n_heads;
    // Round-robin one head per problem per sweep: a quantum spans
    // problems (the interleave), and consecutive sweeps keep a
    // problem's tasks in their cache-friendly emission order.
    while remaining > 0 {
        for (p, cursor) in cursors.iter_mut().enumerate() {
            if *cursor >= heads_per_problem[p].len() {
                continue;
            }
            let h = heads_per_problem[p][*cursor];
            *cursor += 1;
            remaining -= 1;
            order.push(h);
            cur.tasks.push(h);
            cur.flops += chain_flops(tasks, h);
            if cur.flops >= target {
                quanta.push(std::mem::replace(&mut cur, Quantum { tasks: Vec::new(), flops: 0.0 }));
            }
        }
    }
    if !cur.tasks.is_empty() {
        quanta.push(cur);
    }
    QuantaPlan { order, quanta, target_flops: target }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::types::Trans;
    use crate::task::{taskize_gemm, GemmDesc, TaskSet};

    /// Fuse-free helper: N single-task problems of size n (t = n ⇒ one
    /// tile each), ids offset like the fuser does.
    fn toy_batch(sizes: &[usize]) -> (Vec<crate::task::Task>, Vec<Vec<usize>>) {
        let mut tasks = Vec::new();
        let mut heads = Vec::new();
        for (p, &n) in sizes.iter().enumerate() {
            let d = GemmDesc { ta: Trans::No, tb: Trans::No, m: n, n, k: n, alpha: 1.0, beta: 0.0, t: n };
            let TaskSet { tasks: mut ts, heads: hs } = taskize_gemm(&d);
            let off = tasks.len();
            heads.push(hs.iter().map(|h| h + off).collect());
            for t in &mut ts {
                t.id += off;
                t.p = p;
            }
            tasks.append(&mut ts);
        }
        (tasks, heads)
    }

    #[test]
    fn covers_every_head_exactly_once() {
        let (tasks, heads) = toy_batch(&[8, 16, 32, 8, 24]);
        let plan = plan_quanta(&tasks, &heads, 4);
        let mut seen = plan.order.clone();
        seen.sort_unstable();
        let mut expect: Vec<usize> = heads.iter().flatten().copied().collect();
        expect.sort_unstable();
        assert_eq!(seen, expect);
        // quanta concatenate to the order
        let cat: Vec<usize> = plan.quanta.iter().flat_map(|q| q.tasks.iter().copied()).collect();
        assert_eq!(cat, plan.order);
    }

    #[test]
    fn interleaves_problems_round_robin() {
        let (tasks, heads) = toy_batch(&[8, 8, 8]);
        let plan = plan_quanta(&tasks, &heads, 2);
        // first three emitted heads come from three distinct problems
        let ps: Vec<usize> = plan.order[..3].iter().map(|&h| tasks[h].p).collect();
        assert_eq!(ps, vec![0, 1, 2]);
    }

    #[test]
    fn quanta_are_flop_balanced() {
        // 64 uniform single-tile problems on 4 workers ⇒ ~16 quanta of
        // ~4 tasks each; no quantum more than double the target.
        let sizes = vec![16usize; 64];
        let (tasks, heads) = toy_batch(&sizes);
        let plan = plan_quanta(&tasks, &heads, 4);
        assert!(plan.quanta.len() >= 8, "expected many quanta, got {}", plan.quanta.len());
        for q in &plan.quanta {
            assert!(q.flops <= 2.0 * plan.target_flops + 1.0, "{} vs {}", q.flops, plan.target_flops);
        }
    }

    #[test]
    fn chains_account_successor_flops() {
        // two-task chain: head's quantum cost covers both links
        let (mut tasks, heads) = toy_batch(&[8, 8]);
        tasks[0].successor = Some(1);
        tasks[1].n_deps = 1;
        let only_heads = vec![vec![0], heads[1].clone()];
        let plan = plan_quanta(&tasks, &only_heads, 1);
        let chained = plan.quanta.iter().find(|q| q.tasks.contains(&0)).unwrap();
        assert!(chained.flops >= tasks[0].flops + tasks[1].flops);
    }

    #[test]
    fn empty_batch_yields_empty_plan() {
        let plan = plan_quanta(&[], &[], 4);
        assert!(plan.order.is_empty());
        assert!(plan.quanta.is_empty());
    }
}
