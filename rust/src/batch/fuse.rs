//! Batch fusion: N per-problem task sets → one problem-namespaced
//! `TaskSet` whose ready set is emitted in scheduling-quantum order.
//!
//! Fusion is pure renumbering — the per-routine taskizers (Eq. 1a–1f,
//! including the TRSM dependency chains) are reused verbatim, so batch
//! semantics can never drift from single-call semantics. Each problem's
//! tasks get `Task::p` (and every `TileRef::p`) set to the problem
//! index, ids and chain links are offset into the fused vector, and the
//! merged heads are ordered by [`super::quanta::plan_quanta`].

use super::desc::BatchDesc;
use super::quanta;
use crate::task::{taskize_gemm, taskize_syrk, taskize_trsm, TaskSet};

/// Taskize every problem of the batch at tile size `t` and fuse the
/// results. `n_workers` sizes the scheduling quanta (device count, or
/// device count + 1 with the CPU worker).
pub fn taskize_batch(desc: &BatchDesc, t: usize, n_workers: usize) -> TaskSet {
    let sets: Vec<TaskSet> = match desc {
        BatchDesc::Gemm(b) => b
            .problems
            .iter()
            .map(|d| {
                let mut d = *d;
                d.t = t;
                taskize_gemm(&d)
            })
            .collect(),
        BatchDesc::Syrk(b) => b
            .problems
            .iter()
            .map(|d| {
                let mut d = *d;
                d.t = t;
                taskize_syrk(&d)
            })
            .collect(),
        BatchDesc::Trsm(b) => b
            .problems
            .iter()
            .map(|d| {
                let mut d = *d;
                d.t = t;
                taskize_trsm(&d)
            })
            .collect(),
    };
    fuse_batch(sets, n_workers)
}

/// Fuse per-problem task sets into one. Problem `p` of the result is
/// `sets[p]` with ids offset, chain links remapped, and `p` stamped on
/// tasks and tile references; heads are merged in quantum order.
pub fn fuse_batch(sets: Vec<TaskSet>, n_workers: usize) -> TaskSet {
    let total: usize = sets.iter().map(|s| s.tasks.len()).sum();
    let mut tasks = Vec::with_capacity(total);
    let mut heads_per_problem = Vec::with_capacity(sets.len());
    for (p, set) in sets.into_iter().enumerate() {
        let off = tasks.len();
        heads_per_problem.push(set.heads.iter().map(|h| h + off).collect::<Vec<_>>());
        for mut task in set.tasks {
            task.id += off;
            task.p = p;
            if let Some(s) = &mut task.successor {
                *s += off;
            }
            for step in &mut task.steps {
                if let Some(a) = &mut step.a {
                    a.p = p;
                }
                if let Some(b) = &mut step.b {
                    b.p = p;
                }
            }
            tasks.push(task);
        }
    }
    let plan = quanta::plan_quanta(&tasks, &heads_per_problem, n_workers);
    TaskSet { tasks, heads: plan.order }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::types::{Diag, Side, Trans, Uplo};
    use crate::batch::desc::{BatchedGemm, BatchedTrsm};
    use crate::task::{GemmDesc, TriDesc};
    use crate::tile::MatId;

    fn gd(m: usize, n: usize, k: usize) -> GemmDesc {
        GemmDesc { ta: Trans::No, tb: Trans::No, m, n, k, alpha: 1.5, beta: 0.5, t: 0 }
    }

    #[test]
    fn fused_gemm_batch_validates_and_namespaces() {
        let desc = BatchDesc::Gemm(BatchedGemm::variable(vec![
            gd(40, 40, 40),
            gd(65, 33, 17),
            gd(16, 16, 16),
        ]));
        let ts = taskize_batch(&desc, 16, 2);
        ts.validate().unwrap();
        // 3x3 + ceil(65/16)x ceil(33/16)=5x3 + 1x1 tasks
        assert_eq!(ts.tasks.len(), 9 + 15 + 1);
        // problem indices stamped on tasks and every tile ref
        for t in &ts.tasks {
            assert!(t.p < 3);
            for s in &t.steps {
                for r in s.inputs() {
                    assert_eq!(r.p, t.p);
                }
            }
            assert_eq!(t.c_ref().p, t.p);
        }
        // same (ci,cj) exists in different problems — namespacing keeps
        // validate() happy (it would reject duplicates within one).
        assert!(ts.tasks.iter().filter(|t| t.ci == 0 && t.cj == 0).count() >= 3);
        // all problems represented early in the head order (interleave)
        let early: std::collections::HashSet<usize> =
            ts.heads[..3].iter().map(|&h| ts.tasks[h].p).collect();
        assert_eq!(early.len(), 3);
    }

    #[test]
    fn fused_flops_equal_sum_of_parts() {
        let probs = vec![gd(48, 32, 24), gd(24, 24, 24)];
        let sum: f64 = probs
            .iter()
            .map(|d| {
                let mut d = *d;
                d.t = 16;
                taskize_gemm(&d).total_flops()
            })
            .sum();
        let ts = taskize_batch(&BatchDesc::Gemm(BatchedGemm::variable(probs)), 16, 2);
        assert!((ts.total_flops() - sum).abs() < 1e-9 * sum);
    }

    #[test]
    fn trsm_batch_preserves_chains_per_problem() {
        let tri = TriDesc {
            side: Side::Left,
            uplo: Uplo::Upper,
            ta: Trans::No,
            diag: Diag::NonUnit,
            m: 12,
            n: 8,
            alpha: 1.0,
            t: 0,
        };
        let ts = taskize_batch(&BatchDesc::Trsm(BatchedTrsm::uniform(tri, 3)), 4, 2);
        ts.validate().unwrap();
        // per problem: 3x2 tiles, 2 chains of 3 ⇒ 2 heads each
        assert_eq!(ts.heads.len(), 6);
        // successors stay within their problem
        for t in &ts.tasks {
            if let Some(s) = t.successor {
                assert_eq!(ts.tasks[s].p, t.p, "chain crossed problems");
            }
        }
    }

    #[test]
    fn single_problem_fusion_is_identity_modulo_head_order() {
        let d = gd(64, 64, 64);
        let mut single = {
            let mut d = d;
            d.t = 16;
            taskize_gemm(&d)
        };
        let fused = taskize_batch(&BatchDesc::Gemm(BatchedGemm::variable(vec![d])), 16, 2);
        fused.validate().unwrap();
        assert_eq!(single.tasks.len(), fused.tasks.len());
        // identical tasks (p is 0 in both; head order may differ)
        for (a, b) in single.tasks.iter().zip(&fused.tasks) {
            assert_eq!(a.id, b.id);
            assert_eq!((a.ci, a.cj, a.p), (b.ci, b.cj, b.p));
            assert_eq!(a.steps, b.steps);
        }
        single.heads.sort_unstable();
        let mut fh = fused.heads.clone();
        fh.sort_unstable();
        assert_eq!(single.heads, fh);
    }

    #[test]
    fn empty_batch_is_an_empty_task_set() {
        let ts = taskize_batch(&BatchDesc::Gemm(BatchedGemm::variable(vec![])), 16, 2);
        assert!(ts.tasks.is_empty());
        assert!(ts.heads.is_empty());
        ts.validate().unwrap();
        let _ = MatId::A; // keep the import pattern consistent with siblings
    }
}
