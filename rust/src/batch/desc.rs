//! Batch descriptors: per-problem routine descriptions for the three
//! batched routines (GEMM, SYRK, TRSM — the KBLAS core set).
//!
//! A *uniform* batch repeats one prototype descriptor `count` times; a
//! *variable* batch carries heterogeneous shapes/scalars per problem.
//! Either way the batch taskizer normalizes every problem to the
//! runtime's tile size, so the per-problem `t` fields are overwritten
//! at fusion time and callers may leave them 0.

use crate::api::types::Routine;
use crate::task::{GemmDesc, SyrkDesc, TriDesc};
use crate::tile::TileGrid;

/// A batch of GEMM problems `C_i := alpha_i op(A_i) op(B_i) + beta_i C_i`.
#[derive(Clone, Debug)]
pub struct BatchedGemm {
    pub problems: Vec<GemmDesc>,
}

/// A batch of SYRK problems (rank-k updates).
#[derive(Clone, Debug)]
pub struct BatchedSyrk {
    pub problems: Vec<SyrkDesc>,
}

/// A batch of TRSM problems (triangular solves).
#[derive(Clone, Debug)]
pub struct BatchedTrsm {
    pub problems: Vec<TriDesc>,
}

macro_rules! batch_ctors {
    ($name:ident, $desc:ty) => {
        impl $name {
            /// A uniform batch: `count` copies of one prototype.
            pub fn uniform(proto: $desc, count: usize) -> $name {
                $name { problems: vec![proto; count] }
            }

            /// A variable-size batch.
            pub fn variable(problems: Vec<$desc>) -> $name {
                $name { problems }
            }

            pub fn len(&self) -> usize {
                self.problems.len()
            }

            pub fn is_empty(&self) -> bool {
                self.problems.is_empty()
            }
        }
    };
}

batch_ctors!(BatchedGemm, GemmDesc);
batch_ctors!(BatchedSyrk, SyrkDesc);
batch_ctors!(BatchedTrsm, TriDesc);

/// A batch of problems of one routine family.
#[derive(Clone, Debug)]
pub enum BatchDesc {
    Gemm(BatchedGemm),
    Syrk(BatchedSyrk),
    Trsm(BatchedTrsm),
}

impl BatchDesc {
    /// Number of problems in the batch.
    pub fn len(&self) -> usize {
        match self {
            BatchDesc::Gemm(b) => b.len(),
            BatchDesc::Syrk(b) => b.len(),
            BatchDesc::Trsm(b) => b.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The routine family of the batch.
    pub fn routine(&self) -> Routine {
        match self {
            BatchDesc::Gemm(_) => Routine::Gemm,
            BatchDesc::Syrk(_) => Routine::Syrk,
            BatchDesc::Trsm(_) => Routine::Trsm,
        }
    }

    /// Per-problem operand grids in (A, B, C) order at tile size `t` —
    /// the geometry a batch [`crate::coordinator::KeyMap`] needs.
    /// Routines without a distinct B operand reuse A's grid (same
    /// convention as the single-routine workloads).
    pub fn grids(&self, t: usize) -> Vec<[TileGrid; 3]> {
        match self {
            BatchDesc::Gemm(b) => b
                .problems
                .iter()
                .map(|d| {
                    let (ar, ac) = if d.ta == crate::api::types::Trans::No {
                        (d.m, d.k)
                    } else {
                        (d.k, d.m)
                    };
                    let (br, bc) = if d.tb == crate::api::types::Trans::No {
                        (d.k, d.n)
                    } else {
                        (d.n, d.k)
                    };
                    [
                        TileGrid::new(ar, ac, t),
                        TileGrid::new(br, bc, t),
                        TileGrid::new(d.m, d.n, t),
                    ]
                })
                .collect(),
            BatchDesc::Syrk(b) => b
                .problems
                .iter()
                .map(|d| {
                    let (ar, ac) = if d.trans == crate::api::types::Trans::No {
                        (d.n, d.k)
                    } else {
                        (d.k, d.n)
                    };
                    let a = TileGrid::new(ar, ac, t);
                    [a, a, TileGrid::new(d.n, d.n, t)]
                })
                .collect(),
            BatchDesc::Trsm(b) => b
                .problems
                .iter()
                .map(|d| {
                    let na = if d.side == crate::api::types::Side::Left { d.m } else { d.n };
                    let a = TileGrid::new(na, na, t);
                    [a, a, TileGrid::new(d.m, d.n, t)]
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::types::{Diag, Side, Trans, Uplo};

    fn gd(m: usize, n: usize, k: usize) -> GemmDesc {
        GemmDesc { ta: Trans::No, tb: Trans::No, m, n, k, alpha: 1.0, beta: 0.0, t: 0 }
    }

    #[test]
    fn uniform_and_variable_batches() {
        let u = BatchedGemm::uniform(gd(64, 64, 64), 5);
        assert_eq!(u.len(), 5);
        let v = BatchedGemm::variable(vec![gd(10, 20, 30), gd(40, 50, 60)]);
        assert_eq!(v.len(), 2);
        assert!(!v.is_empty());
        assert_eq!(BatchDesc::Gemm(v).routine(), Routine::Gemm);
    }

    #[test]
    fn grids_follow_transposes() {
        let mut d = gd(10, 20, 30);
        d.ta = Trans::Yes;
        let g = BatchDesc::Gemm(BatchedGemm::variable(vec![d])).grids(8);
        assert_eq!(g.len(), 1);
        // op(A) is 10x30, stored A is 30x10
        assert_eq!((g[0][0].rows, g[0][0].cols), (30, 10));
        assert_eq!((g[0][1].rows, g[0][1].cols), (30, 20));
        assert_eq!((g[0][2].rows, g[0][2].cols), (10, 20));
    }

    #[test]
    fn trsm_and_syrk_grids() {
        let s = SyrkDesc { uplo: Uplo::Lower, trans: Trans::Yes, n: 12, k: 8, alpha: 1.0, beta: 1.0, t: 0 };
        let g = BatchDesc::Syrk(BatchedSyrk::uniform(s, 2)).grids(4);
        assert_eq!((g[1][0].rows, g[1][0].cols), (8, 12));
        assert_eq!((g[1][2].rows, g[1][2].cols), (12, 12));

        let t = TriDesc { side: Side::Right, uplo: Uplo::Upper, ta: Trans::No, diag: Diag::NonUnit, m: 6, n: 10, alpha: 1.0, t: 0 };
        let g = BatchDesc::Trsm(BatchedTrsm::uniform(t, 1)).grids(4);
        assert_eq!((g[0][0].rows, g[0][0].cols), (10, 10));
        assert_eq!((g[0][2].rows, g[0][2].cols), (6, 10));
    }
}
