//! Minimal JSON value model, writer and parser.
//!
//! `serde` is not reachable offline, and BLASX only needs JSON for two
//! things: exporting traces/bench results for plotting, and reading the
//! artifact manifest written by `python/compile/aot.py`. This module
//! implements the small subset required (full JSON grammar, UTF-8 strings
//! with standard escapes, f64 numbers).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            // Integral values without trailing ".0" — friendlier output.
            let _ = fmt::Write::write_fmt(out, format_args!("{}", x as i64));
        } else {
            let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
        }
    } else {
        // JSON has no NaN/Inf; emit null like most tolerant writers.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(|x| x.into()).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}
impl std::error::Error for ParseError {}

/// Parse a JSON document. Trailing whitespace is allowed; trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut pos = 0usize;
    let val = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(err(pos, "trailing characters"));
    }
    Ok(val)
}

fn err(at: usize, msg: &str) -> ParseError {
    ParseError { at, msg: msg.to_string() }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err(err(*pos, "unexpected end of input"));
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_num(b, pos),
        c => Err(err(*pos, &format!("unexpected byte {:?}", c as char))),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json, ParseError> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(val)
    } else {
        Err(err(*pos, &format!("expected `{lit}`")))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    let start = *pos;
    if *pos < b.len() && b[*pos] == b'-' {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|_| err(start, "bad utf8 in number"))?;
    s.parse::<f64>().map(Json::Num).map_err(|_| err(start, "bad number"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, ParseError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            return Err(err(*pos, "unterminated string"));
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    return Err(err(*pos, "unterminated escape"));
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err(err(*pos, "truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| err(*pos, "bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| err(*pos, "bad \\u escape"))?;
                        // Surrogate pairs: accept but replace lone ones.
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(err(*pos, &format!("bad escape {:?}", c as char))),
                }
                *pos += 1;
            }
            _ => {
                // Consume one UTF-8 code point.
                let s = std::str::from_utf8(&b[*pos..]).map_err(|_| err(*pos, "bad utf8"))?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    *pos += 1; // consume '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(err(*pos, "expected `,` or `]`")),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, ParseError> {
    *pos += 1; // consume '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(err(*pos, "expected object key"));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(err(*pos, "expected `:`"));
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(err(*pos, "expected `,` or `}`")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let mut o = Json::obj();
        o.set("name", "blasx".into())
            .set("n", 16384usize.into())
            .set("ok", true.into())
            .set("ratio", Json::Num(2.95))
            .set("devices", vec![0usize, 1, 2].into());
        let s = o.to_string_compact();
        let back = parse(&s).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn roundtrip_pretty() {
        let mut o = Json::obj();
        o.set("a", Json::Arr(vec![Json::Null, Json::Bool(false), Json::Num(-1.5)]));
        let back = parse(&o.to_string_pretty()).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#"{"s": "a\nb\t\"q\" A"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn parses_numbers() {
        let v = parse("[-1, 0.5, 1e3, 2.5E-2]").unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), -1.0);
        assert_eq!(arr[1].as_f64().unwrap(), 0.5);
        assert_eq!(arr[2].as_f64().unwrap(), 1000.0);
        assert!((arr[3].as_f64().unwrap() - 0.025).abs() < 1e-15);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("{} x").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::Str("héllo ⊕ 世界".to_string());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"x": 3, "s": "hi", "b": true, "a": [1]}"#).unwrap();
        assert_eq!(v.get("x").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.get("missing").is_none());
    }
}
