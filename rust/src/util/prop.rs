//! Property-based testing helper (proptest is unreachable offline).
//!
//! `Cases` drives a closure over many seeded-random inputs; on failure it
//! reports the case seed so the exact input can be replayed by setting
//! `BLASX_PROP_SEED`. It deliberately mirrors the parts of proptest that
//! the coordinator invariants need: lots of random cases, deterministic
//! replay, and readable failure output. (No shrinking — inputs here are
//! small configuration tuples, so the failing case is directly readable.)

use crate::util::prng::Prng;

/// A property-test driver.
pub struct Cases {
    /// Number of random cases to run.
    pub n: usize,
    /// Base seed; each case uses `splitmix(base, index)`.
    pub seed: u64,
}

impl Default for Cases {
    fn default() -> Self {
        Cases { n: 256, seed: 0xB1A5_F00D }
    }
}

impl Cases {
    pub fn new(n: usize) -> Self {
        Cases { n, ..Default::default() }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run `body` for every case. `body` receives a fresh deterministic
    /// PRNG per case and returns `Err(msg)` to fail the property.
    ///
    /// Panics (test-failure style) on the first failing case, printing
    /// the case index and replay seed.
    pub fn run<F>(&self, name: &str, mut body: F)
    where
        F: FnMut(&mut Prng) -> Result<(), String>,
    {
        // Replay support: BLASX_PROP_SEED=<case_seed> runs one case.
        if let Ok(s) = std::env::var("BLASX_PROP_SEED") {
            if let Ok(seed) = s.parse::<u64>() {
                let mut rng = Prng::new(seed);
                if let Err(msg) = body(&mut rng) {
                    panic!("property `{name}` failed on replay seed {seed}: {msg}");
                }
                return;
            }
        }
        for i in 0..self.n {
            let case_seed = self.seed.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut rng = Prng::new(case_seed);
            if let Err(msg) = body(&mut rng) {
                panic!(
                    "property `{name}` failed on case {i}/{} (replay: BLASX_PROP_SEED={case_seed}): {msg}",
                    self.n
                );
            }
        }
    }
}

/// Assert two slices are element-wise close; returns Err with the first
/// offending index for use inside properties.
pub fn check_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        if (x - y).abs() > tol * scale {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Cases::new(50).run("trivial", |rng| {
            count += 1;
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) { Ok(()) } else { Err("out of range".into()) }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn failing_property_panics_with_seed() {
        Cases::new(4).run("always_fails", |_| Err("nope".into()));
    }

    #[test]
    fn check_close_detects_divergence() {
        assert!(check_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9).is_ok());
        assert!(check_close(&[1.0], &[1.1], 1e-9).is_err());
        assert!(check_close(&[1.0], &[1.0, 2.0], 1e-9).is_err());
    }
}
