//! Small statistics helpers used by the bench harness and metrics.

/// Summary statistics over a sample of f64 values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute summary statistics. Returns a zeroed summary for an empty
    /// sample (callers treat n == 0 as "no data").
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, median: 0.0, p95: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (pct / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Geometric mean of positive values; 0.0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let logsum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (logsum / xs.len() as f64).exp()
}

/// GFLOP/s given flop count and elapsed seconds.
pub fn gflops(flops: f64, secs: f64) -> f64 {
    if secs <= 0.0 { 0.0 } else { flops / secs / 1e9 }
}

/// Parallel efficiency: t1 / (g * tg). Paper Table III's metric.
pub fn parallel_efficiency(t1: f64, tg: f64, g: usize) -> f64 {
    if tg <= 0.0 || g == 0 { 0.0 } else { t1 / (g as f64 * tg) }
}

/// Format a byte count with binary units.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 { format!("{b} B") } else { format!("{v:.2} {}", UNITS[u]) }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.median - 3.0).abs() < 1e-12);
        // sample std of 1..5 = sqrt(2.5)
        assert!((s.std - 2.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[7.0]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        let g = geomean(&[1.0, 100.0]);
        assert!((g - 10.0).abs() < 1e-9);
    }

    #[test]
    fn gflops_sane() {
        // 2e9 flops in 1 second = 2 GFLOP/s
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-12);
        assert_eq!(gflops(1.0, 0.0), 0.0);
    }

    #[test]
    fn efficiency_linear_speedup_is_one() {
        assert!((parallel_efficiency(9.0, 3.0, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(8 * 1024 * 1024).contains("MiB"));
        assert!(fmt_secs(0.5e-3).contains("µs") || fmt_secs(0.5e-3).contains("ms"));
        assert!(fmt_secs(2.0).contains("s"));
    }
}
