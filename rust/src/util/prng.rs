//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so BLASX ships a small,
//! well-understood generator: splitmix64 for seeding and xoshiro256++ for
//! the stream. Determinism matters here — benchmark workloads, property
//! tests and the simulator all want reproducible streams keyed by a seed.

/// splitmix64 step — used to expand a single `u64` seed into a full
/// xoshiro256++ state. Passes into a distinct stream for every call.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator (Blackman & Vigna). Not cryptographic; ample
/// quality for workload generation and property tests.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // A zero state would be a fixed point; splitmix64 never yields
        // four zeros from any seed, but be defensive.
        if s == [0, 0, 0, 0] {
            return Prng { s: [1, 2, 3, 4] };
        }
        Prng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // 128-bit multiply keeps bias < 2^-64 for any practical n.
        (((self.next_u64() as u128) * (n as u128)) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Coin flip with probability `p` of `true`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-300 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_f64(&mut self, buf: &mut [f64], lo: f64, hi: f64) {
        for x in buf.iter_mut() {
            *x = self.range_f64(lo, hi);
        }
    }

    /// Fill a slice with uniform f32 values in `[lo, hi)`.
    pub fn fill_f32(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for x in buf.iter_mut() {
            *x = lo + (hi - lo) * self.next_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Prng {
        Prng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut p = Prng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = p.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn mean_of_uniform_is_half() {
        let mut p = Prng::new(3);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| p.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut p = Prng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| p.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Prng::new(13);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
