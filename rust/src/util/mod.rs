//! Shared utilities: PRNG, statistics, JSON, logging, property testing.
//!
//! These exist because the offline crate set lacks `rand`, `serde`,
//! `criterion` and `proptest`; each submodule is a deliberately small,
//! fully tested replacement for the subset BLASX needs.

pub mod json;
pub mod logger;
pub mod once;
pub mod prng;
pub mod prop;
pub mod stats;
