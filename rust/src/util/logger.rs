//! A tiny self-contained stderr logger (the `log` facade crate is
//! unreachable offline, and nothing in the crate needs more than a
//! leveled eprintln).
//!
//! Controlled by `BLASX_LOG` (off|error|warn|info|debug|trace, default
//! warn). Every diagnostic the library emits goes through here — the
//! xerbla path, the fault plane, serve-mode warnings — so one
//! environment knob silences or amplifies all of them consistently.
//!
//! Hot paths (a fault schedule hammering retries, a backpressured
//! admission loop) use [`log_limited`]: per-site rate limiting caps
//! emission at [`MAX_PER_WINDOW`] lines per site per second and then
//! reports how many were suppressed when the window rolls, so a
//! misbehaving fleet cannot turn stderr into the bottleneck.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::{Duration, Instant};

/// Log severity, most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Lines a single site may emit per [`RATE_WINDOW`] before
/// [`log_limited`] starts suppressing.
pub const MAX_PER_WINDOW: u32 = 8;
/// Rate-limit window.
pub const RATE_WINDOW: Duration = Duration::from_secs(1);

/// Current max level as its numeric value (0 = off; Warn before init).
static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);
static INIT: Once = Once::new();

/// Per-site rate-limit ledger, keyed by the `target` string.
struct Site {
    window_start: Instant,
    emitted: u32,
    suppressed: u64,
}

fn sites() -> &'static Mutex<HashMap<String, Site>> {
    static SITES: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();
    SITES.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Install the logger (idempotent). Reads `BLASX_LOG` for the level.
/// Called lazily by every emission path, so explicit init is optional.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("BLASX_LOG").as_deref() {
            Ok("off") | Ok("none") | Ok("0") => 0,
            Ok("error") => Level::Error as u8,
            Ok("warn") => Level::Warn as u8,
            Ok("info") => Level::Info as u8,
            Ok("debug") => Level::Debug as u8,
            Ok("trace") => Level::Trace as u8,
            _ => Level::Warn as u8,
        };
        LEVEL.store(level, Ordering::Relaxed);
    });
}

/// Would a message at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one message (already formatted) if the level is enabled.
pub fn log(level: Level, target: &str, msg: &str) {
    if enabled(level) {
        eprintln!("[blasx {:5} {}] {}", level.tag(), target, msg);
    }
}

/// [`log`] with per-site rate limiting: at most [`MAX_PER_WINDOW`]
/// lines per `target` per [`RATE_WINDOW`]; overflow is counted and
/// reported in one summary line when the window rolls. Returns whether
/// the message itself was emitted (tests).
pub fn log_limited(level: Level, target: &str, msg: &str) -> bool {
    if !enabled(level) {
        return false;
    }
    let mut sites = sites().lock().unwrap_or_else(|e| e.into_inner());
    let now = Instant::now();
    let site = sites.entry(target.to_string()).or_insert(Site {
        window_start: now,
        emitted: 0,
        suppressed: 0,
    });
    if now.duration_since(site.window_start) >= RATE_WINDOW {
        if site.suppressed > 0 {
            eprintln!(
                "[blasx {:5} {}] ... {} similar message(s) suppressed in the last {:?}",
                level.tag(),
                target,
                site.suppressed,
                RATE_WINDOW,
            );
        }
        site.window_start = now;
        site.emitted = 0;
        site.suppressed = 0;
    }
    if site.emitted < MAX_PER_WINDOW {
        site.emitted += 1;
        drop(sites);
        eprintln!("[blasx {:5} {}] {}", level.tag(), target, msg);
        true
    } else {
        site.suppressed += 1;
        false
    }
}

/// Convenience: warn-level message.
pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

/// Convenience: error-level message (the xerbla path).
pub fn error(target: &str, msg: &str) {
    log(Level::Error, target, msg);
}

/// Convenience: rate-limited warn (fault-plane and backpressure
/// hot paths).
pub fn warn_limited(target: &str, msg: &str) -> bool {
    log_limited(Level::Warn, target, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        warn("logger", "logger smoke test");
        assert!(enabled(Level::Error));
    }

    #[test]
    fn rate_limit_caps_a_hot_site() {
        // The first MAX_PER_WINDOW lines of a burst emit; the rest of
        // the window suppresses. Use a dedicated target so parallel
        // tests can't share the ledger entry.
        let target = "logger-test-burst";
        let mut emitted = 0;
        for i in 0..(MAX_PER_WINDOW * 3) {
            if log_limited(Level::Error, target, &format!("burst {i}")) {
                emitted += 1;
            }
        }
        assert_eq!(emitted, MAX_PER_WINDOW, "burst must be capped per window");
    }

    #[test]
    fn distinct_sites_do_not_share_budgets() {
        assert!(log_limited(Level::Error, "logger-test-site-a", "x"));
        for _ in 0..MAX_PER_WINDOW {
            log_limited(Level::Error, "logger-test-site-b", "y");
        }
        // Site B exhausted its budget; site A still has its own.
        assert!(!log_limited(Level::Error, "logger-test-site-b", "y"));
        assert!(log_limited(Level::Error, "logger-test-site-a", "x"));
    }
}
