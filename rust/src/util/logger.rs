//! A tiny self-contained stderr logger (the `log` facade crate is
//! unreachable offline, and nothing in the crate needs more than a
//! leveled eprintln).
//!
//! Controlled by `BLASX_LOG` (error|warn|info|debug|trace, default warn).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

/// Log severity, most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Current max level as its numeric value (Warn before init()).
static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);
static INIT: Once = Once::new();

/// Install the logger (idempotent). Reads `BLASX_LOG` for the level.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("BLASX_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("info") => Level::Info,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Warn,
        };
        LEVEL.store(level as u8, Ordering::Relaxed);
    });
}

/// Would a message at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one message (already formatted) if the level is enabled.
pub fn log(level: Level, target: &str, msg: &str) {
    if enabled(level) {
        eprintln!("[blasx {:5} {}] {}", level.tag(), target, msg);
    }
}

/// Convenience: warn-level message.
pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        warn("logger", "logger smoke test");
        assert!(enabled(Level::Error));
    }
}
