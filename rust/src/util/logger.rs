//! A tiny `log`-facade backend writing to stderr.
//!
//! Controlled by `BLASX_LOG` (error|warn|info|debug|trace, default warn).

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::Once;

struct StderrLogger {
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[blasx {:5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger (idempotent). Reads `BLASX_LOG` for the level.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("BLASX_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("info") => Level::Info,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            Ok("warn") | _ => Level::Warn,
        };
        let logger = Box::leak(Box::new(StderrLogger { level }));
        if log::set_logger(logger).is_ok() {
            log::set_max_level(LevelFilter::Trace.min(level.to_level_filter()));
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::warn!("logger smoke test");
    }
}
