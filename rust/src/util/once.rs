//! A `OnceCell` with fallible initialization (`once_cell` is
//! unreachable offline; std's `OnceLock::get_or_try_init` is not yet
//! stable). Built from `OnceLock` + an init mutex: the lock serializes
//! initializers so a failing one can be retried, while reads after
//! initialization go through the lock-free `OnceLock` fast path.

use std::sync::{Mutex, OnceLock};

/// A thread-safe cell initialized at most once, with `Result`-returning
/// initializers.
pub struct OnceCell<T> {
    cell: OnceLock<T>,
    init: Mutex<()>,
}

impl<T> OnceCell<T> {
    pub const fn new() -> OnceCell<T> {
        OnceCell { cell: OnceLock::new(), init: Mutex::new(()) }
    }

    /// The value, if initialized.
    pub fn get(&self) -> Option<&T> {
        self.cell.get()
    }

    /// Set the value if the cell is still empty; hands the value back
    /// if another initializer already won.
    pub fn set(&self, v: T) -> Result<(), T> {
        let _guard = self.init.lock().unwrap_or_else(|e| e.into_inner());
        self.cell.set(v)
    }

    /// Get the value, running `f` to create it if empty. If `f` fails
    /// the cell stays empty and a later call may retry.
    pub fn get_or_try_init<F, E>(&self, f: F) -> Result<&T, E>
    where
        F: FnOnce() -> Result<T, E>,
    {
        if let Some(v) = self.cell.get() {
            return Ok(v);
        }
        let _guard = self.init.lock().unwrap_or_else(|e| e.into_inner());
        // Re-check under the lock: another thread may have won the race.
        if self.cell.get().is_none() {
            let v = f()?;
            let _ = self.cell.set(v);
        }
        Ok(self.cell.get().expect("OnceCell set under init lock"))
    }

    /// Infallible variant.
    pub fn get_or_init<F>(&self, f: F) -> &T
    where
        F: FnOnce() -> T,
    {
        match self.get_or_try_init::<_, std::convert::Infallible>(|| Ok(f())) {
            Ok(v) => v,
            Err(never) => match never {},
        }
    }
}

impl<T> Default for OnceCell<T> {
    fn default() -> Self {
        OnceCell::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failed_init_can_retry() {
        let c: OnceCell<u32> = OnceCell::new();
        assert!(c.get_or_try_init(|| Err::<u32, &str>("nope")).is_err());
        assert_eq!(c.get(), None);
        assert_eq!(*c.get_or_try_init(|| Ok::<u32, &str>(7)).unwrap(), 7);
        // Subsequent initializers are ignored.
        assert_eq!(*c.get_or_try_init(|| Ok::<u32, &str>(9)).unwrap(), 7);
        assert_eq!(c.get(), Some(&7));
    }

    #[test]
    fn set_wins_only_while_empty() {
        let c: OnceCell<u32> = OnceCell::new();
        assert!(c.set(3).is_ok());
        assert_eq!(c.set(4), Err(4));
        assert_eq!(*c.get_or_init(|| 9), 3);
    }

    #[test]
    fn concurrent_init_runs_once() {
        let c: OnceCell<usize> = OnceCell::new();
        let hits = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let v = c.get_or_init(|| {
                        hits.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        42
                    });
                    assert_eq!(*v, 42);
                });
            }
        });
        assert_eq!(hits.load(std::sync::atomic::Ordering::SeqCst), 1);
    }
}
