//! Michael–Scott non-blocking concurrent queue (paper §IV-C.4).
//!
//! BLASX uses "a non-blocking queue allowing efficient concurrent dequeue
//! and enqueue operations based on the algorithm proposed by Maged and
//! Michael" — i.e. Michael & Scott, PODC '96. This is a faithful
//! implementation of the two-lock-free-pointer (head/tail) linked queue
//! with CAS on both ends.
//!
//! ## Memory reclamation
//! The original algorithm assumes a type-stable allocator. Instead of
//! hazard pointers we use *deferred reclamation*: dequeued nodes are
//! pushed onto a lock-free Treiber retire-stack and only freed when the
//! queue itself is dropped. For BLASX this is the right trade-off — a
//! routine invocation enqueues O(#tiles) small nodes, all retired by the
//! time the call returns, so "free at drop" bounds memory by the task
//! count while keeping the hot path wait-free of locks.

use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

struct Node<T> {
    value: Option<T>,
    next: AtomicPtr<Node<T>>,
}

impl<T> Node<T> {
    fn new(value: Option<T>) -> *mut Node<T> {
        Box::into_raw(Box::new(Node { value, next: AtomicPtr::new(ptr::null_mut()) }))
    }
}

/// A multi-producer multi-consumer lock-free FIFO queue.
pub struct MsQueue<T> {
    head: AtomicPtr<Node<T>>,
    tail: AtomicPtr<Node<T>>,
    /// Treiber stack of retired nodes awaiting reclamation.
    retired: AtomicPtr<Node<T>>,
    /// Approximate length (exact under quiescence) for demand metrics.
    len: AtomicUsize,
}

unsafe impl<T: Send> Send for MsQueue<T> {}
unsafe impl<T: Send> Sync for MsQueue<T> {}

impl<T> Default for MsQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> MsQueue<T> {
    pub fn new() -> Self {
        // dummy node: head and tail both point at it
        let dummy = Node::new(None);
        MsQueue {
            head: AtomicPtr::new(dummy),
            tail: AtomicPtr::new(dummy),
            retired: AtomicPtr::new(ptr::null_mut()),
            len: AtomicUsize::new(0),
        }
    }

    /// Enqueue at the tail (lock-free).
    pub fn enqueue(&self, value: T) {
        let node = Node::new(Some(value));
        loop {
            let tail = self.tail.load(Ordering::Acquire);
            // SAFETY: tail is never freed while the queue is alive
            // (retired nodes come only from dequeue's head-swing).
            let next = unsafe { (*tail).next.load(Ordering::Acquire) };
            if tail != self.tail.load(Ordering::Acquire) {
                continue; // tail moved under us
            }
            if next.is_null() {
                // try to link node at the end of the list
                if unsafe { &(*tail).next }
                    .compare_exchange(ptr::null_mut(), node, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    // enqueue done; swing tail (failure is fine — someone helped)
                    let _ = self.tail.compare_exchange(
                        tail,
                        node,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    );
                    self.len.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            } else {
                // help swing tail forward
                let _ =
                    self.tail.compare_exchange(tail, next, Ordering::AcqRel, Ordering::Acquire);
            }
        }
    }

    /// Dequeue from the head (lock-free). Returns `None` when empty.
    pub fn dequeue(&self) -> Option<T> {
        loop {
            let head = self.head.load(Ordering::Acquire);
            let tail = self.tail.load(Ordering::Acquire);
            // SAFETY: head node is alive until retired by a successful
            // head-swing below; retired nodes are not freed until drop.
            let next = unsafe { (*head).next.load(Ordering::Acquire) };
            if head != self.head.load(Ordering::Acquire) {
                continue;
            }
            if head == tail {
                if next.is_null() {
                    return None; // empty
                }
                // tail lagging; help
                let _ =
                    self.tail.compare_exchange(tail, next, Ordering::AcqRel, Ordering::Acquire);
            } else if self
                .head
                .compare_exchange(head, next, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                // We won the head swing, so we have exclusive claim on
                // `next`'s value. (The original M&S reads the value
                // *before* the CAS because a winning dequeuer may free
                // the node; our deferred reclamation keeps `next` alive
                // until Drop, so reading after the CAS is safe and
                // avoids a value-restore race.)
                let value = unsafe { (*next).value.take() };
                debug_assert!(value.is_some(), "dequeued node had no value");
                self.len.fetch_sub(1, Ordering::Relaxed);
                self.retire(head);
                return value;
            }
        }
    }

    /// Push a retired node onto the reclamation stack.
    fn retire(&self, node: *mut Node<T>) {
        loop {
            let top = self.retired.load(Ordering::Acquire);
            unsafe {
                (*node).next.store(top, Ordering::Relaxed);
            }
            if self
                .retired
                .compare_exchange(top, node, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
        }
    }

    /// Approximate number of queued items.
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for MsQueue<T> {
    fn drop(&mut self) {
        // free the live list
        let mut cur = self.head.load(Ordering::Relaxed);
        while !cur.is_null() {
            let next = unsafe { (*cur).next.load(Ordering::Relaxed) };
            drop(unsafe { Box::from_raw(cur) });
            cur = next;
        }
        // free the retired stack
        let mut cur = self.retired.load(Ordering::Relaxed);
        while !cur.is_null() {
            let next = unsafe { (*cur).next.load(Ordering::Relaxed) };
            drop(unsafe { Box::from_raw(cur) });
            cur = next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = MsQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.dequeue(), None);
        for i in 0..100 {
            q.enqueue(i);
        }
        assert_eq!(q.len(), 100);
        for i in 0..100 {
            assert_eq!(q.dequeue(), Some(i));
        }
        assert_eq!(q.dequeue(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_ops() {
        let q = MsQueue::new();
        q.enqueue(1);
        q.enqueue(2);
        assert_eq!(q.dequeue(), Some(1));
        q.enqueue(3);
        assert_eq!(q.dequeue(), Some(2));
        assert_eq!(q.dequeue(), Some(3));
        assert_eq!(q.dequeue(), None);
        q.enqueue(4);
        assert_eq!(q.dequeue(), Some(4));
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        const PRODUCERS: usize = 4;
        const CONSUMERS: usize = 4;
        const PER: usize = 5_000;
        let q = Arc::new(MsQueue::new());
        let got = Arc::new(std::sync::Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            for p in 0..PRODUCERS {
                let q = q.clone();
                s.spawn(move || {
                    for i in 0..PER {
                        q.enqueue(p * PER + i);
                    }
                });
            }
            for _ in 0..CONSUMERS {
                let q = q.clone();
                let got = got.clone();
                s.spawn(move || {
                    let mut local = Vec::new();
                    let mut misses = 0;
                    while local.len() < PRODUCERS * PER && misses < 1_000_000 {
                        match q.dequeue() {
                            Some(v) => local.push(v),
                            None => {
                                misses += 1;
                                std::hint::spin_loop();
                            }
                        }
                        // stop once globally done
                        if misses % 1024 == 0 {
                            let total: usize =
                                got.lock().unwrap().len() + local.len();
                            if total >= PRODUCERS * PER && q.is_empty() {
                                break;
                            }
                        }
                    }
                    got.lock().unwrap().extend(local);
                });
            }
        });
        let mut all = got.lock().unwrap().clone();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), PRODUCERS * PER, "every item exactly once");
    }

    #[test]
    fn fifo_order_per_producer() {
        // With one producer and one consumer, strict FIFO must hold even
        // under concurrency.
        let q = Arc::new(MsQueue::new());
        let qc = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..20_000u64 {
                qc.enqueue(i);
            }
        });
        let mut last = None;
        let mut seen = 0;
        while seen < 20_000 {
            if let Some(v) = q.dequeue() {
                if let Some(l) = last {
                    assert!(v > l, "FIFO violated: {v} after {l}");
                }
                last = Some(v);
                seen += 1;
            } else {
                std::hint::spin_loop();
            }
        }
        producer.join().unwrap();
    }

    #[test]
    fn drop_reclaims_pending_items() {
        // Drop a non-empty queue holding heap values: must not leak/crash.
        let q = MsQueue::new();
        for i in 0..100 {
            q.enqueue(vec![i; 100]);
        }
        for _ in 0..50 {
            let _ = q.dequeue();
        }
        drop(q);
    }
}
