//! Non-blocking task queue (system S3).
//!
//! [`ms_queue::MsQueue`] implements Michael & Scott's lock-free FIFO, the
//! algorithm the paper cites for its global task queue; BLASX's work
//! sharing is "processors simultaneously pull out tasks … by their
//! demands" from this queue (§IV-C).

pub mod ms_queue;

pub use ms_queue::MsQueue;
