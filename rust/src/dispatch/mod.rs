//! Adaptive per-shape dispatch (the tile-size barrier's replacement as
//! the system's tuning story).
//!
//! The paper tunes ONE parameter — the tile size — and the pre-PR-8
//! runtime made changing it between calls catastrophically expensive (a
//! full admission barrier plus a global cache purge). With the tile
//! size folded into [`crate::tile::TileKey`], mixed geometries are
//! free, which unlocks *choosing* the geometry per call: this module
//! picks, per `(routine, m, n, k, dtype)`:
//!
//! - the tile size `t` (a per-geometry cache generation),
//! - the kernel-thread fan-out of each tile task,
//! - the serial/fork flop cutoff of `hostblas::gemm_mt`
//!   ([`RunConfig::mt_cutoff`](crate::coordinator::RunConfig)),
//! - host-vs-device placement (small problems skip tiling/staging
//!   entirely and run on the host through
//!   `Runtime::submit_host`, still admission-ordered).
//!
//! Choices come from three sources, in priority order:
//! 1. a **recorded profile** ([`Profile`], JSON; produced by the
//!    `blasx tune` shape-grid sweep in [`sweep`], loadable via
//!    `Context::with_profile`, the `BLASX_PROFILE` env var, or the C
//!    ABI's `blasx_config_t.profile`),
//! 2. **online feedback** (per-shape throughput EWMAs refined from
//!    call reports in adaptive mode — deterministic round-robin
//!    exploration of the `t` candidates, then exploitation),
//! 3. a **static heuristic** (sub-tile problems go to the host; `t`
//!    shrinks until a call has enough output tiles to spread across
//!    devices).
//!
//! The dispatcher is strictly **opt-in**: a `Context` without one
//! behaves exactly as before (fixed `cfg.t`, device placement), so
//! every existing caller and test is unaffected.

pub mod sweep;

use crate::api::Dtype;
use crate::error::{Error, Result};
use crate::util::json::{parse, Json};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Tile sizes the heuristic/adaptive/sweep layers choose between.
/// Bounded below by kernel register blocking (64) and above by what a
/// sane arena holds (512² f64 = 2 MiB/tile).
pub const T_CANDIDATES: [usize; 4] = [64, 128, 256, 512];

/// Where a call executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Tiled, through the device engine (the default).
    Device,
    /// One host kernel shot, admission-ordered but never staged
    /// (`Runtime::submit_host`). Only taken for blocking GEMM.
    Host,
}

impl Placement {
    pub fn name(self) -> &'static str {
        match self {
            Placement::Device => "device",
            Placement::Host => "host",
        }
    }

    pub fn from_name(s: &str) -> Option<Placement> {
        match s {
            "device" => Some(Placement::Device),
            "host" => Some(Placement::Host),
            _ => None,
        }
    }
}

/// One dispatch decision: everything the API layer stamps onto a call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Choice {
    /// Tile size (its own cache generation — see `crate::tile::TileKey`).
    pub t: usize,
    /// Kernel-thread fan-out per tile task (`RunConfig::worker_threads`).
    pub kernel_threads: usize,
    /// Serial/fork flop cutoff override for `hostblas::gemm_mt`
    /// (`None` = the process-wide `hostblas::mt_flop_cutoff()`).
    pub mt_cutoff: Option<f64>,
    pub place: Placement,
}

impl Choice {
    fn to_json(self) -> Json {
        let mut o = Json::obj();
        o.set("t", self.t.into())
            .set("kernel_threads", self.kernel_threads.into())
            .set(
                "mt_cutoff",
                self.mt_cutoff.map_or(Json::Null, Json::Num),
            )
            .set("place", self.place.name().into());
        o
    }

    fn from_json(v: &Json) -> Option<Choice> {
        let t = v.get("t")?.as_usize()?;
        if t == 0 {
            return None;
        }
        let kernel_threads = v.get("kernel_threads")?.as_usize()?.max(1);
        let mt_cutoff = match v.get("mt_cutoff") {
            None | Some(Json::Null) => None,
            Some(x) => Some(x.as_f64()?).filter(|&c| c.is_finite() && c > 0.0),
        };
        let place = Placement::from_name(v.get("place")?.as_str()?)?;
        Some(Choice { t, kernel_threads, mt_cutoff, place })
    }
}

/// Power-of-two shape bucket: problems within a ×2 band share a
/// dispatch decision, so a compact sweep generalizes.
fn bucket(x: usize) -> u32 {
    x.max(1).next_power_of_two().trailing_zeros()
}

/// The profile/EWMA key of a call shape: `"gemm/f64/m7n7k7"` for a
/// GEMM with every dimension in (64, 128].
pub fn shape_key(routine: &str, dtype: Dtype, m: usize, n: usize, k: usize) -> String {
    let dt = match dtype {
        Dtype::F32 => "f32",
        Dtype::F64 => "f64",
    };
    format!("{routine}/{dt}/m{}n{}k{}", bucket(m), bucket(n), bucket(k))
}

/// A recorded dispatch table: shape-bucket key → [`Choice`].
/// Persistable as JSON (`blasx tune --out profile.json`), loadable by
/// `Context::with_profile` / `BLASX_PROFILE` / the C ABI.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Profile {
    entries: BTreeMap<String, Choice>,
}

impl Profile {
    pub fn new() -> Profile {
        Profile::default()
    }

    pub fn set(&mut self, key: String, choice: Choice) {
        self.entries.insert(key, choice);
    }

    pub fn get(&self, key: &str) -> Option<&Choice> {
        self.entries.get(key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut table = Json::obj();
        for (k, c) in &self.entries {
            table.set(k, c.to_json());
        }
        let mut o = Json::obj();
        o.set("schema", "blasx-profile-v1".into()).set("choices", table);
        o
    }

    pub fn from_json(v: &Json) -> Result<Profile> {
        match v.get("schema").and_then(Json::as_str) {
            Some("blasx-profile-v1") => {}
            other => {
                return Err(Error::Config(format!(
                    "not a blasx dispatch profile (schema {other:?})"
                )))
            }
        }
        let Some(Json::Obj(table)) = v.get("choices") else {
            return Err(Error::Config("profile has no `choices` object".into()));
        };
        let mut p = Profile::new();
        for (k, cv) in table {
            let c = Choice::from_json(cv).ok_or_else(|| {
                Error::Config(format!("malformed profile choice for shape {k}"))
            })?;
            p.set(k.clone(), c);
        }
        Ok(p)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .map_err(|e| Error::Config(format!("cannot write profile {path}: {e}")))
    }

    pub fn load(path: &str) -> Result<Profile> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read profile {path}: {e}")))?;
        let v = parse(&text)
            .map_err(|e| Error::Config(format!("profile {path} is not JSON: {e}")))?;
        Profile::from_json(&v)
    }
}

/// Per-(shape, t) online throughput estimate.
#[derive(Clone, Copy, Debug, Default)]
struct Ewma {
    gflops: f64,
    n: u64,
}

const EWMA_ALPHA: f64 = 0.3;

impl Ewma {
    fn observe(&mut self, gflops: f64) {
        self.gflops = if self.n == 0 {
            gflops
        } else {
            EWMA_ALPHA * gflops + (1.0 - EWMA_ALPHA) * self.gflops
        };
        self.n += 1;
    }
}

/// Minimum observations of a `t` before its EWMA may win over the
/// exploration rotation.
const MIN_OBS: u64 = 2;

/// The per-context dispatch brain. Deterministic: [`Dispatcher::choose`]
/// depends only on the profile, the sequence of prior
/// [`Dispatcher::observe`] calls for the same shape bucket, and the
/// static heuristic — never on wall-clock or randomness.
#[derive(Debug)]
pub struct Dispatcher {
    profile: Profile,
    /// Online throughput EWMAs: shape key → (t → estimate). Only
    /// consulted/extended in adaptive mode.
    online: Mutex<BTreeMap<String, BTreeMap<usize, Ewma>>>,
    adaptive: bool,
}

impl Dispatcher {
    /// Dispatch from a recorded profile, falling back to the static
    /// heuristic for unseen shapes. No online refinement: a profile
    /// reproduces identical choices call after call (the round-trip
    /// guarantee `blasx tune` relies on).
    pub fn from_profile(profile: Profile) -> Dispatcher {
        Dispatcher { profile, online: Mutex::new(BTreeMap::new()), adaptive: false }
    }

    /// Dispatch adaptively: start from the heuristic (or `profile`
    /// entries where present), explore the `t` candidates in a
    /// deterministic rotation, then exploit the best observed EWMA.
    pub fn adaptive(profile: Profile) -> Dispatcher {
        Dispatcher { profile, online: Mutex::new(BTreeMap::new()), adaptive: true }
    }

    pub fn is_adaptive(&self) -> bool {
        self.adaptive
    }

    /// Online-EWMA state for the telemetry gauges: `(shape buckets
    /// tracked, total observations folded in)`.
    pub fn online_stats(&self) -> (usize, u64) {
        let online = self.online.lock().unwrap_or_else(|e| e.into_inner());
        let obs = online.values().flat_map(|m| m.values()).map(|e| e.n).sum();
        (online.len(), obs)
    }

    /// The static no-measurement fallback. `base` carries the context
    /// defaults (its `cfg.t`, `cfg.worker_threads`, ...).
    pub fn heuristic(routine: &str, m: usize, n: usize, k: usize, base: &Choice) -> Choice {
        // A problem that fits inside ONE tile of the context's own
        // geometry gains nothing from the tiled engine (one task, no
        // parallelism) and pays staging for it: run it on the host,
        // still admission-ordered. Only GEMM has a host fast path.
        if routine == "gemm" && m.max(n).max(k) <= base.t && m * n * k > 0 {
            return Choice { place: Placement::Host, ..*base };
        }
        // Otherwise shrink t until the output plane has enough tiles
        // to spread across devices and streams (≥ 8, the engine's
        // round working set), starting from the largest candidate not
        // above the context default.
        let mut t = base.t;
        for &cand in T_CANDIDATES.iter().rev() {
            if cand > base.t {
                continue;
            }
            t = cand;
            if m.div_ceil(cand) * n.div_ceil(cand) >= 8 {
                break;
            }
        }
        Choice { t, ..*base }
    }

    /// Decide the call's configuration. Priority: exact profile entry →
    /// adaptive explore/exploit (adaptive mode only) → heuristic.
    pub fn choose(
        &self,
        routine: &str,
        dtype: Dtype,
        m: usize,
        n: usize,
        k: usize,
        base: &Choice,
    ) -> Choice {
        let key = shape_key(routine, dtype, m, n, k);
        if let Some(c) = self.profile.get(&key) {
            return *c;
        }
        let fallback = Self::heuristic(routine, m, n, k, base);
        if !self.adaptive || fallback.place == Placement::Host {
            return fallback;
        }
        let online = self.online.lock().unwrap_or_else(|e| e.into_inner());
        let Some(stats) = online.get(&key) else { return fallback };
        // Candidates eligible on this context (never above the base
        // geometry — the arena was sized for it).
        let cands: Vec<usize> =
            T_CANDIDATES.iter().copied().filter(|&c| c <= base.t).collect();
        if cands.is_empty() {
            return fallback;
        }
        let total_obs: u64 = stats.values().map(|e| e.n).sum();
        // Exploration: give every candidate MIN_OBS measurements, in
        // rotation order keyed by the observation count (deterministic
        // for a deterministic call sequence).
        if let Some(&t) = cands
            .iter()
            .find(|&&c| stats.get(&c).map_or(0, |e| e.n) < MIN_OBS)
        {
            let idx = (total_obs as usize) % cands.len();
            // Rotate the start so a single under-observed candidate
            // doesn't monopolize the probe budget.
            let t = cands[idx..]
                .iter()
                .chain(&cands[..idx])
                .copied()
                .find(|c| stats.get(c).map_or(0, |e| e.n) < MIN_OBS)
                .unwrap_or(t);
            return Choice { t, ..fallback };
        }
        // Exploitation: argmax EWMA throughput.
        let best = cands
            .iter()
            .copied()
            .max_by(|&a, &b| {
                let ga = stats.get(&a).map_or(0.0, |e| e.gflops);
                let gb = stats.get(&b).map_or(0.0, |e| e.gflops);
                ga.partial_cmp(&gb).unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap_or(fallback.t);
        Choice { t: best, ..fallback }
    }

    /// Feed a call's measured outcome back (adaptive mode; a no-op
    /// otherwise). `elapsed_s` is wall time of the blocking call.
    pub fn observe(
        &self,
        routine: &str,
        dtype: Dtype,
        m: usize,
        n: usize,
        k: usize,
        t_used: usize,
        elapsed_s: f64,
    ) {
        if !self.adaptive || elapsed_s <= 0.0 {
            return;
        }
        let gflops = 2.0 * m as f64 * n as f64 * k as f64 / elapsed_s / 1e9;
        let key = shape_key(routine, dtype, m, n, k);
        let mut online = self.online.lock().unwrap_or_else(|e| e.into_inner());
        online.entry(key).or_default().entry(t_used).or_default().observe(gflops);
        // First-touch bootstrap: make the shape visible to choose()
        // even before any alternative t has run.
    }

    /// The dispatcher's current knowledge as a profile: recorded
    /// entries plus, in adaptive mode, the online winner of every
    /// fully-explored shape. What `blasx tune` persists after a sweep.
    pub fn snapshot_profile(&self, base: &Choice) -> Profile {
        let mut p = self.profile.clone();
        let online = self.online.lock().unwrap_or_else(|e| e.into_inner());
        for (key, stats) in online.iter() {
            if p.get(key).is_some() {
                continue;
            }
            let done = stats.values().filter(|e| e.n >= MIN_OBS).count() >= 2;
            if !done {
                continue;
            }
            if let Some((&t, _)) = stats.iter().max_by(|a, b| {
                a.1.gflops.partial_cmp(&b.1.gflops).unwrap_or(std::cmp::Ordering::Equal)
            }) {
                p.set(key.clone(), Choice { t, ..*base });
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Choice {
        Choice { t: 256, kernel_threads: 1, mt_cutoff: None, place: Placement::Device }
    }

    #[test]
    fn buckets_are_pow2_bands() {
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(64), 6);
        assert_eq!(bucket(65), 7);
        assert_eq!(bucket(128), 7);
        assert_eq!(bucket(129), 8);
        assert_eq!(shape_key("gemm", Dtype::F64, 100, 128, 65), "gemm/f64/m7n7k7");
        assert_ne!(
            shape_key("gemm", Dtype::F32, 100, 100, 100),
            shape_key("gemm", Dtype::F64, 100, 100, 100)
        );
    }

    #[test]
    fn profile_json_roundtrip() {
        let mut p = Profile::new();
        p.set(
            "gemm/f64/m9n9k9".into(),
            Choice { t: 128, kernel_threads: 4, mt_cutoff: Some(2e6), place: Placement::Device },
        );
        p.set(
            "gemm/f64/m6n6k6".into(),
            Choice { t: 64, kernel_threads: 1, mt_cutoff: None, place: Placement::Host },
        );
        let text = p.to_json().to_string_pretty();
        let back = Profile::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn profile_rejects_garbage() {
        assert!(Profile::from_json(&parse("{}").unwrap()).is_err());
        let bad = r#"{"schema":"blasx-profile-v1","choices":{"x":{"t":0,"kernel_threads":1,"place":"device"}}}"#;
        assert!(Profile::from_json(&parse(bad).unwrap()).is_err());
        let bad_place = r#"{"schema":"blasx-profile-v1","choices":{"x":{"t":64,"kernel_threads":1,"place":"moon"}}}"#;
        assert!(Profile::from_json(&parse(bad_place).unwrap()).is_err());
    }

    #[test]
    fn profile_entries_are_deterministic_choices() {
        let mut p = Profile::new();
        let key = shape_key("gemm", Dtype::F64, 300, 300, 300);
        let want =
            Choice { t: 128, kernel_threads: 2, mt_cutoff: Some(1e6), place: Placement::Device };
        p.set(key, want);
        let d = Dispatcher::from_profile(p);
        for _ in 0..5 {
            assert_eq!(d.choose("gemm", Dtype::F64, 300, 300, 300, &base()), want);
        }
        // Same bucket, different exact shape: same choice.
        assert_eq!(d.choose("gemm", Dtype::F64, 257, 270, 260, &base()), want);
    }

    #[test]
    fn heuristic_places_subtile_gemm_on_host() {
        let c = Dispatcher::heuristic("gemm", 64, 64, 64, &base());
        assert_eq!(c.place, Placement::Host);
        // Any dimension above the tile → device.
        let c = Dispatcher::heuristic("gemm", 64, 300, 64, &base());
        assert_eq!(c.place, Placement::Device);
        // Degenerate problems stay on the normal path.
        let c = Dispatcher::heuristic("gemm", 0, 64, 64, &base());
        assert_eq!(c.place, Placement::Device);
        // Non-GEMM routines never go to the host.
        let c = Dispatcher::heuristic("syrk", 64, 64, 64, &base());
        assert_eq!(c.place, Placement::Device);
    }

    #[test]
    fn heuristic_shrinks_t_for_parallelism() {
        // 600×600 at t=256 is a 3×3 = 9-tile plane: big enough.
        assert_eq!(Dispatcher::heuristic("gemm", 600, 600, 600, &base()).t, 256);
        // 300×300 at t=256 is 2×2 = 4 tiles; at 128 it's 3×3 = 9.
        assert_eq!(Dispatcher::heuristic("gemm", 300, 300, 300, &base()).t, 128);
        // Never grows above the context geometry.
        let small = Choice { t: 64, ..base() };
        assert_eq!(Dispatcher::heuristic("gemm", 4000, 4000, 4000, &small).t, 64);
    }

    #[test]
    fn adaptive_explores_then_exploits_deterministically() {
        let d = Dispatcher::adaptive(Profile::new());
        let b = base();
        let (m, n, k) = (300, 300, 300);
        // Drive a fixed feedback schedule: t=64 is fastest.
        let speed = |t: usize| match t {
            64 => 100.0,
            128 => 60.0,
            256 => 30.0,
            _ => 1.0,
        };
        let mut seen = Vec::new();
        for _ in 0..12 {
            let c = d.choose("gemm", Dtype::F64, m, n, k, &b);
            seen.push(c.t);
            let gflops_target = speed(c.t);
            let elapsed = 2.0 * (m * n * k) as f64 / (gflops_target * 1e9);
            d.observe("gemm", Dtype::F64, m, n, k, c.t, elapsed);
        }
        // Converged on the fastest candidate.
        assert_eq!(*seen.last().unwrap(), 64, "sequence: {seen:?}");
        // And the whole sequence is reproducible.
        let d2 = Dispatcher::adaptive(Profile::new());
        let mut seen2 = Vec::new();
        for _ in 0..12 {
            let c = d2.choose("gemm", Dtype::F64, m, n, k, &b);
            seen2.push(c.t);
            let elapsed = 2.0 * (m * n * k) as f64 / (speed(c.t) * 1e9);
            d2.observe("gemm", Dtype::F64, m, n, k, c.t, elapsed);
        }
        assert_eq!(seen, seen2, "adaptive dispatch must be deterministic");
    }

    #[test]
    fn snapshot_profile_records_online_winners() {
        let d = Dispatcher::adaptive(Profile::new());
        let b = base();
        let (m, n, k) = (300, 300, 300);
        for _ in 0..10 {
            let c = d.choose("gemm", Dtype::F64, m, n, k, &b);
            let gf = if c.t == 128 { 90.0 } else { 20.0 };
            d.observe("gemm", Dtype::F64, m, n, k, c.t, 2.0 * (m * n * k) as f64 / (gf * 1e9));
        }
        let p = d.snapshot_profile(&b);
        let key = shape_key("gemm", Dtype::F64, m, n, k);
        assert_eq!(p.get(&key).map(|c| c.t), Some(128));
    }
}
