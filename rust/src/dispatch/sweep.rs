//! The `blasx tune` shape-grid sweep: measure a compact grid of square
//! GEMMs across the tile-size candidates (extending the spirit of the
//! `hostblas::tune` KC/MC probe one level up, to whole-call geometry)
//! and record the winners as a [`Profile`].
//!
//! The grid is deliberately small — a handful of shapes, seconds of
//! wall time — because the profile keys are ×2 shape *buckets*: each
//! measured point generalizes to its whole band, and unseen bands fall
//! back to the heuristic. Timing here only ever changes *performance*
//! decisions (tile size, fan-out, placement), never numerics.

use super::{shape_key, Choice, Placement, Profile, T_CANDIDATES};
use crate::api::types::Trans;
use crate::api::{l3, Context, Dtype};
use crate::hostblas;
use crate::util::prng::Prng;
use std::time::Instant;

/// What to sweep. The defaults ([`SweepOpts::full`]) take a few
/// seconds; [`SweepOpts::quick`] is the CI smoke variant.
#[derive(Clone, Debug)]
pub struct SweepOpts {
    pub n_devices: usize,
    pub arena_bytes: usize,
    /// Square GEMM sizes for the device-side tile-size sweep.
    pub shapes: Vec<usize>,
    /// Sub-tile sizes for the host-vs-device placement probe.
    pub small_shapes: Vec<usize>,
    /// Timing repetitions per point (the minimum is kept).
    pub reps: usize,
}

impl SweepOpts {
    pub fn full() -> SweepOpts {
        SweepOpts {
            n_devices: 2,
            arena_bytes: 64 << 20,
            shapes: vec![256, 448, 768],
            small_shapes: vec![64, 128],
            reps: 2,
        }
    }

    pub fn quick() -> SweepOpts {
        SweepOpts {
            n_devices: 2,
            arena_bytes: 32 << 20,
            shapes: vec![192],
            small_shapes: vec![96],
            reps: 1,
        }
    }
}

/// Seconds for one tiled n×n×n dgemm at tile size `t` with
/// `kernel_threads` fan-out, on a fresh one-shot engine (cold staging
/// included — that's part of what the choice pays for).
fn time_tiled(n: usize, t: usize, kernel_threads: usize, opts: &SweepOpts) -> f64 {
    let ctx = Context::new(opts.n_devices)
        .with_arena(opts.arena_bytes)
        .with_tile(t)
        .with_kernel_threads(kernel_threads)
        .with_persistent(false);
    let mut rng = Prng::new(97);
    let mut a = vec![0.0f64; n * n];
    let mut b = vec![0.0f64; n * n];
    let mut c = vec![0.0f64; n * n];
    rng.fill_f64(&mut a, -1.0, 1.0);
    rng.fill_f64(&mut b, -1.0, 1.0);
    let mut best = f64::INFINITY;
    for _ in 0..opts.reps.max(1) {
        c.fill(0.0);
        let t0 = Instant::now();
        l3::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n)
            .expect("sweep gemm");
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Seconds for one host-path n×n×n dgemm (what `Placement::Host`
/// executes).
fn time_host(n: usize, reps: usize) -> f64 {
    let mut rng = Prng::new(98);
    let mut a = vec![0.0f64; n * n];
    let mut b = vec![0.0f64; n * n];
    let mut c = vec![0.0f64; n * n];
    rng.fill_f64(&mut a, -1.0, 1.0);
    rng.fill_f64(&mut b, -1.0, 1.0);
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        c.fill(0.0);
        let t0 = Instant::now();
        hostblas::gemm_mt(1, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Run the sweep and return the recorded profile. `log` receives one
/// human-readable line per measured point (the CLI passes a printer;
/// tests pass `|_| {}`).
pub fn sweep(opts: &SweepOpts, mut log: impl FnMut(&str)) -> Profile {
    let mut prof = Profile::new();
    for &n in &opts.shapes {
        let mut best: Option<(usize, f64)> = None;
        for &t in T_CANDIDATES.iter().filter(|&&t| t <= n) {
            if opts.arena_bytes < 8 * t * t * 8 {
                continue; // arena can't hold a round's working set
            }
            let secs = time_tiled(n, t, 1, opts);
            log(&format!("  gemm n={n} t={t}: {:.1} ms", secs * 1e3));
            if best.map_or(true, |(_, b)| secs < b) {
                best = Some((t, secs));
            }
        }
        let Some((t, tiled_secs)) = best else { continue };
        // Does fanning each tile kernel across threads pay at this
        // shape? (Big tiles fork internally; small ones stay serial
        // under the flop cutoff either way.)
        let mt_secs = time_tiled(n, t, 4, opts);
        log(&format!("  gemm n={n} t={t} kt=4: {:.1} ms", mt_secs * 1e3));
        let kernel_threads = if mt_secs < tiled_secs { 4 } else { 1 };
        prof.set(
            shape_key("gemm", Dtype::F64, n, n, n),
            Choice { t, kernel_threads, mt_cutoff: None, place: Placement::Device },
        );
    }
    for &n in &opts.small_shapes {
        let t = T_CANDIDATES.iter().copied().filter(|&t| t <= n).max().unwrap_or(T_CANDIDATES[0]);
        let host = time_host(n, opts.reps);
        let tiled = time_tiled(n, t.min(n), 1, opts);
        log(&format!(
            "  gemm n={n}: host {:.2} ms vs tiled {:.2} ms",
            host * 1e3,
            tiled * 1e3
        ));
        let place = if host <= tiled { Placement::Host } else { Placement::Device };
        prof.set(
            shape_key("gemm", Dtype::F64, n, n, n),
            Choice { t, kernel_threads: 1, mt_cutoff: None, place },
        );
    }
    prof
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_produces_a_loadable_profile() {
        // A deliberately minuscule grid: this checks plumbing (sweep →
        // profile → JSON → profile), not measurement quality.
        let opts = SweepOpts {
            n_devices: 1,
            arena_bytes: 8 << 20,
            shapes: vec![96],
            small_shapes: vec![48],
            reps: 1,
        };
        let prof = sweep(&opts, |_| {});
        assert_eq!(prof.len(), 2, "one grid entry + one placement entry");
        let text = prof.to_json().to_string_pretty();
        let back = Profile::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, prof);
        // The measured grid point must be a device-placement choice
        // with a candidate tile size.
        let c = back.get(&shape_key("gemm", Dtype::F64, 96, 96, 96)).unwrap();
        assert!(T_CANDIDATES.contains(&c.t));
    }
}
