//! Hand-rolled CLI for the `blasx` binary (no clap offline).
//!
//! Subcommands:
//! - `run`   — execute a routine in the real engine and verify numerics
//! - `serve` — multi-client stress mode over the resident runtime
//!   (`--verify` adds scope-async chains, `--ffi-verify` drives the C
//!   ABI entry points against the safe path bit-for-bit)
//! - `tune`  — shape-grid sweep recording a dispatch profile
//!   (`crate::dispatch::sweep`); `run`/`serve` consume it via
//!   `--profile`
//! - `sim`   — simulate a routine on a paper machine under any policy
//! - `gantt` — render the Fig. 1-style ASCII execution profile
//! - `info`  — artifact + machine inventory
//! - `header` — emit the generated C header (`include/blasx.h`)

use crate::api::types::Routine;
use crate::api::Dtype;
use crate::coordinator::{run_sim, square_workload, Policy, RunConfig};
use crate::sim::{everest, makalu, toy, Machine};
use crate::trace::{all_profiles, comm_volumes, gantt};
use crate::util::stats::{fmt_bytes, fmt_secs, gflops};
use std::collections::HashMap;

/// Parsed key=value flags plus positionals.
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

/// Parse `--key value` / `--key=value` / positionals.
pub fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(stripped.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                flags.insert(stripped.to_string(), "true".to_string());
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    Args { positional, flags }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Resolve `--persistent[=bool]` / `--no-persistent` (default on).
    pub fn persistent(&self) -> bool {
        if self.get("no-persistent").is_some() {
            return false;
        }
        !matches!(self.get("persistent"), Some("false" | "0" | "off" | "no"))
    }
}

fn parse_routine(name: &str) -> Option<Routine> {
    let base = |s: &str| match s {
        "gemm" => Some(Routine::Gemm),
        "syrk" => Some(Routine::Syrk),
        "syr2k" => Some(Routine::Syr2k),
        "trmm" => Some(Routine::Trmm),
        "trsm" => Some(Routine::Trsm),
        "symm" => Some(Routine::Symm),
        _ => None,
    };
    // accept bare names and single precision prefixes (dgemm, ssyr2k)
    base(name).or_else(|| {
        name.strip_prefix(['d', 's'])
            .and_then(base)
    })
}

fn parse_machine(name: &str, gpus: usize) -> Machine {
    match name {
        "everest" => everest(gpus.min(3).max(1)),
        "makalu" => makalu(gpus.min(4).max(1)),
        _ => toy(gpus.max(1), 64 << 20),
    }
}

pub fn usage() -> &'static str {
    "blasx — BLASX reproduction (Wang et al. 2015) in Rust + JAX + Pallas

USAGE:
  blasx sim   [--routine dgemm] [--n 8192] [--t 1024] [--machine everest]
              [--gpus 3] [--policy blasx|cublasxt|magma|supermatrix|parsec]
              [--cpu] [--no-steal]
  blasx gantt [--routine dgemm] [--n 4096] ... (sim flags) [--width 100]
              [--json out.json]
  blasx run   [--routine dgemm] [--n 1024] [--t 256] [--devices 2] [--pjrt]
              [--kernel-threads 1] [--repeat 1] [--no-persistent]
              [--profile profile.json] [--adaptive] [--prefetch 0]
              [--trace-out trace.json] [--metrics-out metrics.json]
  blasx serve [--clients 4] [--jobs 8] [--n 512] [--t 256] [--devices 2]
              [--kernel-threads 1] [--verify] [--ffi-verify]
              [--profile profile.json]
              [--chaos] [--faults \"kill@dev1:op40; h2d@dev0:op5x2\"]
              [--deadline-ms 0] [--max-inflight 256] [--tenant-quota 64]
              [--trace-out trace.json] [--metrics-out metrics.json]
              [--telemetry-addr 127.0.0.1:9464] [--telemetry-ms 100]
              [--flight-dir incidents/] [--linger-ms 0]
  blasx top   [--addr 127.0.0.1:9464] [--interval-ms 1000] [--iters 0]
  blasx tune  [--out profile.json] [--quick] [--devices 2] [--reps 2]
              [--shapes 256,448,768] [--small-shapes 64,128]
  blasx batch <workload.json> [--devices 2] [--t 256] [--pjrt] [--fused]
              [--kernel-threads 1] [--no-persistent]
  blasx header [--out include/blasx.h]
  blasx info

`sim` runs the discrete-event engine on a paper machine and prints the
paper's metrics (GFLOPS, per-GPU profile, comm volume). `run` executes
real numerics through the threaded runtime and checks them against the
host oracle; the persistent device runtime is ON by default (worker
threads, arenas and tile caches survive across calls — `--repeat N`
shows warm calls dropping their host transfers to zero; disable with
`--no-persistent` or `--persistent false`). `batch` executes a JSON
workload script:
  [{\"routine\": \"dgemm\", \"n\": 1024, \"m\": 512, \"k\": 256}, ...]
(square defaults when m/k omitted; routines: gemm/syrk/syr2k/symm/trmm/trsm).
With `--fused` a gemm-only script runs through `dgemm_batched`: every
problem fused into ONE scheduler invocation (problem-namespaced tiles,
work-centric quanta) instead of a per-call loop — the high-throughput
path for many small problems.

`serve` is the multi-tenant stress mode: `--clients` threads share ONE
persistent context and each issues `--jobs` DGEMMs concurrently — the
runtime admits them as concurrent jobs (disjoint buffers overlap on
the devices; the scheduler interleaves rounds under flop-weighted
fairness) and reports jobs/sec plus the worker-idle fraction.
`--verify` checks every client's last result against the host oracle
AND runs an aliasing dgemm→dtrsm chain per client through the
scope-async API (`Context::scope`), asserting bit-for-bit equality
with serial execution. `--ffi-verify` instead drives the C ABI
(`cblas_dgemm` row+column major, `cblas_dtrsm`, and an aliasing
`blasx_dgemm_async`→`blasx_dtrsm_async` chain) against the safe path,
bit-for-bit. `header` prints (or writes with `--out`) the generated C
header that ships as include/blasx.h.

Fault tolerance (serve): `--chaos` arms the default chaos schedule
(kill the last device early, transient kernel/H2D failures on dev 0 —
seeded via `--seed`); `--faults SPEC` installs an explicit schedule in
the BLASX_FAULTS grammar. Under either, jobs migrate off lost devices
and results must STILL verify bit-for-bit (combine with `--verify`).
`--deadline-ms N` reaps jobs that overrun N ms; `--max-inflight` /
`--tenant-quota` bound admission (rejected calls fail fast with a
backpressure error). The stress report then includes per-tenant
rejected/retried/degraded/migrated counters.

Adaptive dispatch: `tune` measures a compact shape grid (tile-size
candidates, kernel fan-out, host-vs-device placement for sub-tile
problems) and records the winners as a JSON profile keyed by ×2 shape
buckets. `run`/`serve` load it with `--profile FILE`: every call then
gets its bucket's recorded tile size/fan-out/placement, deterministically
(mixed tile sizes coexist in the warm caches — each geometry is its own
cache generation, no barrier, no purge). `run --adaptive` instead
refines choices online from call feedback. Library callers use
`Context::with_profile{,_file}` / `with_adaptive_dispatch`, or the
BLASX_PROFILE env var through the C ABI.

Observability (run/serve): `--trace-out FILE` enables the span
recorder and writes a Chrome trace-event JSON (open in Perfetto or
chrome://tracing; one track per device worker, one per admitted job);
`run` then also prints the paper's COMPT/COMM/OTHER split, H<->D /
P2P volumes, and the comm-hidden-under-compute overlap fraction from
the real spans. `run --prefetch K` arms the lookahead transfer
pipeline: each device worker stages up to K upcoming input tiles
ahead of demand (`BLASX_PREFETCH_DEPTH` from the environment; results
are bit-identical either way — see README \"Transfer pipeline &
prefetch\"). `--metrics-out FILE` dumps the
metrics-registry snapshot (per-tenant and per-routine latency
percentiles, worker busy fractions). BLASX_TRACE=1 enables the
recorder from the environment. See README \"Observability\".

Live telemetry (serve): `--telemetry-addr HOST:PORT` serves live
gauges over HTTP — `/metrics` in Prometheus text format (arena bytes,
windowed cache hit rates, queue depth, per-tenant in-flight, worker
busy fractions) and `/healthz` (503 once any device is dead). Every
scrape gathers a fresh sample; `--telemetry-ms N` additionally runs
the background sampler every N ms for history (`BLASX_TELEMETRY_MS`
from the environment; 0/unset = off, zero threads, zero allocation).
`--linger-ms N` keeps the endpoint up N ms after the workload drains
so external scrapers can land. `blasx top` renders a refreshing
terminal view from any such endpoint. `--flight-dir DIR` arms the
always-on flight recorder's auto-dump: on a device kill, deadline
reap, or worker panic the last ~256 events per device are written as
an incident report (JSON + Chrome trace) naming the dead devices —
`BLASX_FLIGHT_DIR` arms it from the environment. See README \"Live
telemetry & flight recorder\"."
}

/// Entry point used by main.rs; returns a process exit code.
pub fn dispatch(argv: &[String]) -> i32 {
    let args = parse_args(argv);
    match args.positional.first().map(String::as_str) {
        Some("sim") => cmd_sim(&args, false),
        Some("gantt") => cmd_sim(&args, true),
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("top") => cmd_top(&args),
        Some("tune") => cmd_tune(&args),
        Some("batch") => cmd_batch(&args),
        Some("header") => cmd_header(&args),
        Some("info") => cmd_info(),
        _ => {
            println!("{}", usage());
            2
        }
    }
}

/// Parse a comma-separated size list (`--shapes 256,448`).
fn parse_sizes(s: &str) -> Option<Vec<usize>> {
    s.split(',').map(|x| x.trim().parse().ok()).collect()
}

/// `blasx tune`: run the dispatch shape-grid sweep and persist the
/// recorded profile (consumed by `run`/`serve` `--profile`,
/// `Context::with_profile_file`, or BLASX_PROFILE).
fn cmd_tune(args: &Args) -> i32 {
    use crate::dispatch::sweep::{sweep, SweepOpts};

    let mut opts = if args.get("quick").is_some() { SweepOpts::quick() } else { SweepOpts::full() };
    opts.n_devices = args.get_usize("devices", opts.n_devices).max(1);
    opts.reps = args.get_usize("reps", opts.reps).max(1);
    if let Some(s) = args.get("shapes") {
        match parse_sizes(s) {
            Some(v) => opts.shapes = v,
            None => {
                eprintln!("tune: bad --shapes list (want e.g. 256,448,768)");
                return 2;
            }
        }
    }
    if let Some(s) = args.get("small-shapes") {
        match parse_sizes(s) {
            Some(v) => opts.small_shapes = v,
            None => {
                eprintln!("tune: bad --small-shapes list (want e.g. 64,128)");
                return 2;
            }
        }
    }
    let out = args.get("out").unwrap_or("profile.json");
    println!(
        "TUNE devices={} shapes={:?} small-shapes={:?} reps={}",
        opts.n_devices, opts.shapes, opts.small_shapes, opts.reps
    );
    let prof = sweep(&opts, |line| println!("{line}"));
    if prof.is_empty() {
        eprintln!("tune: sweep produced no entries (empty shape grid?)");
        return 1;
    }
    match prof.save(out) {
        Ok(()) => {
            println!("profile with {} entries written to {out}", prof.len());
            0
        }
        Err(e) => {
            eprintln!("tune: {e}");
            1
        }
    }
}

/// Emit the generated C header (stdout, or `--out path`).
fn cmd_header(args: &Args) -> i32 {
    let text = crate::ffi::header::render();
    match args.get("out") {
        Some(path) => match std::fs::write(path, &text) {
            Ok(()) => {
                println!("wrote {path}");
                0
            }
            Err(e) => {
                eprintln!("header: cannot write {path}: {e}");
                1
            }
        },
        None => {
            print!("{text}");
            0
        }
    }
}

/// `serve --ffi-verify`: drive the C ABI entry points against the safe
/// path, bit-for-bit — the drop-in acceptance check, runnable without
/// a C compiler (the exports are plain functions to Rust).
fn ffi_verify() -> i32 {
    use crate::api::{self, types::Diag, types::Side, types::Trans, types::Uplo};
    use crate::ffi::{self, capi, cblas};
    use crate::util::prng::Prng;

    // The safe serial reference mirrors the FFI default context's
    // geometry (same tile size ⇒ same decomposition ⇒ bit-for-bit).
    let dc = ffi::default_context();
    let serial = api::Context::new(dc.n_devices)
        .with_tile(dc.cfg.t)
        .with_arena(dc.arena_bytes)
        .with_kernel_threads(dc.cfg.worker_threads)
        .with_persistent(false);
    let (m, n, k) = (96usize, 80, 64);
    let mut p = Prng::new(77);
    let mut a = vec![0.0f64; m * k];
    let mut b = vec![0.0f64; k * n];
    let mut c0 = vec![0.0f64; m * n];
    p.fill_f64(&mut a, -1.0, 1.0);
    p.fill_f64(&mut b, -1.0, 1.0);
    p.fill_f64(&mut c0, -1.0, 1.0);
    // Declare the inputs per the C invalidation contract (the default
    // context is process-global and warm across invocations).
    let declare = |buf: &[f64]| unsafe {
        capi::blasx_invalidate_host(
            buf.as_ptr() as *const core::ffi::c_void,
            std::mem::size_of_val(buf),
        )
    };
    declare(&a);
    declare(&b);
    let (mi, ni, ki) = (m as i32, n as i32, k as i32);
    let mut failures = 0;
    let mut check = |name: &str, ok: bool| {
        println!("  ffi-verify {name}: {}", if ok { "OK (bit-for-bit)" } else { "FAILED" });
        if !ok {
            failures += 1;
        }
    };

    // 1. Column-major cblas_dgemm vs the safe path.
    let mut c_ffi = c0.clone();
    // SAFETY: slices sized to the exact BLAS footprints below.
    unsafe {
        cblas::cblas_dgemm(
            ffi::CBLAS_COL_MAJOR, ffi::CBLAS_NO_TRANS, ffi::CBLAS_NO_TRANS, mi, ni, ki, 1.25,
            a.as_ptr(), mi, b.as_ptr(), ki, -0.5, c_ffi.as_mut_ptr(), mi,
        );
    }
    let mut c_safe = c0.clone();
    api::dgemm(&serial, Trans::No, Trans::No, m, n, k, 1.25, &a, m, &b, k, -0.5, &mut c_safe, m)
        .expect("safe dgemm");
    check("cblas_dgemm (col-major)", c_ffi == c_safe);

    // 2. Row-major cblas_dgemm: row-major buffers are the transposed
    //    col-major ones; the result must transpose back to the same C.
    let mut a_rm = vec![0.0f64; m * k];
    let mut b_rm = vec![0.0f64; k * n];
    let mut c_rm = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..k {
            a_rm[i * k + j] = a[j * m + i];
        }
    }
    for i in 0..k {
        for j in 0..n {
            b_rm[i * n + j] = b[j * k + i];
        }
    }
    for i in 0..m {
        for j in 0..n {
            c_rm[i * n + j] = c0[j * m + i];
        }
    }
    declare(&a_rm);
    declare(&b_rm);
    // SAFETY: row-major buffers sized to the same footprints.
    unsafe {
        cblas::cblas_dgemm(
            ffi::CBLAS_ROW_MAJOR, ffi::CBLAS_NO_TRANS, ffi::CBLAS_NO_TRANS, mi, ni, ki, 1.25,
            a_rm.as_ptr(), ki, b_rm.as_ptr(), ni, -0.5, c_rm.as_mut_ptr(), ni,
        );
    }
    let mut roundtrip = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            roundtrip[j * m + i] = c_rm[i * n + j];
        }
    }
    check("cblas_dgemm (row-major)", roundtrip == c_safe);

    // 3. cblas_dtrsm vs the safe path (in place).
    let mut tri = vec![0.0f64; m * m];
    p.fill_f64(&mut tri, -0.1, 0.1);
    for i in 0..m {
        tri[i * m + i] = 2.0;
    }
    declare(&tri);
    let mut x_ffi = c_safe.clone();
    // SAFETY: footprints as above; B is in/out and disjoint from A.
    unsafe {
        cblas::cblas_dtrsm(
            ffi::CBLAS_COL_MAJOR, ffi::CBLAS_LEFT, ffi::CBLAS_UPPER, ffi::CBLAS_NO_TRANS,
            ffi::CBLAS_NON_UNIT, mi, ni, 1.0, tri.as_ptr(), mi, x_ffi.as_mut_ptr(), mi,
        );
    }
    let mut x_safe = c_safe.clone();
    api::trsm(&serial, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0, &tri, m, &mut x_safe, m)
        .expect("safe trsm");
    check("cblas_dtrsm", x_ffi == x_safe);

    // 4. Aliasing async chain: C := A·B, then solve tri·X = C in place
    //    on the SAME buffer — the admission RAW edge orders the two
    //    C-ABI jobs exactly like the serial pair above.
    let mut c_async = c0.clone();
    // SAFETY: all buffers outlive the blasx_wait calls below.
    let (j1, j2) = unsafe {
        (
            capi::blasx_dgemm_async(
                ffi::CBLAS_COL_MAJOR, ffi::CBLAS_NO_TRANS, ffi::CBLAS_NO_TRANS, mi, ni, ki,
                1.25, a.as_ptr(), mi, b.as_ptr(), ki, -0.5, c_async.as_mut_ptr(), mi,
            ),
            capi::blasx_dtrsm_async(
                ffi::CBLAS_COL_MAJOR, ffi::CBLAS_LEFT, ffi::CBLAS_UPPER, ffi::CBLAS_NO_TRANS,
                ffi::CBLAS_NON_UNIT, mi, ni, 1.0, tri.as_ptr(), mi, c_async.as_mut_ptr(), mi,
            ),
        )
    };
    let ok = !j1.is_null() && !j2.is_null();
    // Wait newest-first: order must not matter.
    let (s2, s1) = unsafe { (capi::blasx_wait(j2), capi::blasx_wait(j1)) };
    check("blasx_*_async aliasing chain", ok && s1 == 0 && s2 == 0 && c_async == x_safe);

    if failures == 0 {
        println!("  ffi-verify: all checks passed");
        0
    } else {
        eprintln!("  ffi-verify: {failures} check(s) FAILED");
        1
    }
}

/// Multi-client stress mode: N threads share one persistent context
/// and hammer the multi-tenant scheduler with independent DGEMMs.
fn cmd_serve(args: &Args) -> i32 {
    use crate::api::{self, types::Trans};
    use crate::util::json::Json;
    use crate::util::prng::Prng;

    if args.get("ffi-verify").is_some() {
        return ffi_verify();
    }

    let clients = args.get_usize("clients", 4).max(1);
    let jobs = args.get_usize("jobs", 8).max(1);
    let n = args.get_usize("n", 512);
    let t = args.get_usize("t", 256);
    let devices = args.get_usize("devices", 2);
    let verify = args.get("verify").is_some();
    let trace_out = args.get("trace-out").map(str::to_string);
    let metrics_out = args.get("metrics-out").map(str::to_string);
    let mut ctx = api::Context::new(devices)
        .with_tile(t)
        .with_kernel_threads(args.get_usize("kernel-threads", 1));
    if let Some(path) = args.get("profile") {
        ctx = match ctx.with_profile_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("serve: {e}");
                return 2;
            }
        };
    }
    // Fault-tolerance knobs: an explicit schedule beats the default
    // chaos plan; both install at runtime boot.
    let plan = if let Some(spec) = args.get("faults") {
        match crate::fault::FaultPlan::parse(spec) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("serve: bad --faults schedule: {e}");
                return 2;
            }
        }
    } else if args.get("chaos").is_some() {
        Some(crate::fault::FaultPlan::chaos_default(
            devices,
            args.get_usize("seed", 7) as u64,
        ))
    } else {
        None
    };
    let chaos = plan.is_some();
    if let Some(p) = plan {
        ctx = ctx.with_fault_plan(Some(p));
    }
    if let Some(ms) = args.get("deadline-ms").and_then(|v| v.parse().ok()) {
        ctx = ctx.with_deadline_ms(Some(ms));
    }
    if let Some(cap) = args.get("max-inflight").and_then(|v| v.parse().ok()) {
        ctx = ctx.with_admit_capacity(cap);
    }
    if let Some(q) = args.get("tenant-quota").and_then(|v| v.parse().ok()) {
        ctx = ctx.with_tenant_quota(q);
    }
    // Live telemetry plane: an explicit --telemetry-ms runs the
    // background sampler; the scrape endpoint works either way (each
    // scrape gathers a fresh sample).
    if let Some(ms) = args.get("telemetry-ms").and_then(|v| v.parse().ok()) {
        ctx = ctx.with_telemetry_ms(Some(ms));
    }
    if trace_out.is_some() {
        ctx.set_tracing(true);
    }
    if let Some(dir) = args.get("flight-dir") {
        ctx.set_flight_dir(Some(std::path::PathBuf::from(dir)));
    }
    let telemetry_server = match args.get("telemetry-addr") {
        None => None,
        Some(addr) => match crate::trace::TelemetryServer::start(addr, ctx.clone()) {
            Ok(s) => {
                println!("  telemetry: http://{}/metrics (+ /healthz)", s.addr());
                Some(s)
            }
            Err(e) => {
                eprintln!("serve: cannot bind telemetry endpoint {addr}: {e}");
                return 2;
            }
        },
    };

    println!(
        "SERVE clients={clients} jobs={jobs} DGEMM N={n} T={t} devices={devices}{}",
        if chaos { " [chaos armed]" } else { "" }
    );

    // Warm the runtime (boot + first-touch) outside the timed window.
    {
        let a = vec![1.0f64; n * n];
        let b = vec![1.0f64; n * n];
        let mut c = vec![0.0f64; n * n];
        if let Err(e) =
            api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n)
        {
            eprintln!("serve: warm-up failed: {e}");
            return 1;
        }
    }
    let busy0: u64 = ctx.runtime_busy_nanos().iter().sum();
    let start = std::time::Instant::now();
    let failed = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| {
        for client in 0..clients {
            let ctx = ctx.clone();
            let failed = &failed;
            scope.spawn(move || {
                let mut p = Prng::new(1000 + client as u64);
                let mut a = vec![0.0f64; n * n];
                let mut b = vec![0.0f64; n * n];
                let mut c = vec![0.0f64; n * n];
                p.fill_f64(&mut a, -1.0, 1.0);
                p.fill_f64(&mut b, -1.0, 1.0);
                ctx.invalidate_host(&a);
                ctx.invalidate_host(&b);
                for _ in 0..jobs {
                    if let Err(e) = api::dgemm(
                        &ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n,
                    ) {
                        eprintln!("serve[client {client}]: {e}");
                        failed.store(true, std::sync::atomic::Ordering::SeqCst);
                        return;
                    }
                }
                if verify {
                    let mut want = vec![0.0f64; n * n];
                    crate::hostblas::gemm_blocked(
                        Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want, n,
                    );
                    let diff = c
                        .iter()
                        .zip(&want)
                        .map(|(x, y)| (x - y).abs())
                        .fold(0.0f64, f64::max);
                    if diff > 1e-9 {
                        eprintln!("serve[client {client}]: verification failed ({diff})");
                        failed.store(true, std::sync::atomic::Ordering::SeqCst);
                    }
                    // Scope path: an aliasing dgemm→dtrsm chain (the
                    // trsm reads AND overwrites the dgemm's output —
                    // the RAW edge orders the two in-flight jobs), must
                    // be bit-for-bit what serial one-shot execution
                    // produces.
                    let mut tri = vec![0.0f64; n * n];
                    p.fill_f64(&mut tri, -0.05, 0.05);
                    for i in 0..n {
                        tri[i * n + i] = 2.0;
                    }
                    ctx.invalidate_host(&tri);
                    let mut chain = vec![0.0f64; n * n];
                    let scope_res = ctx.scope(|s| {
                        use crate::api::types::{Diag, Side, Uplo};
                        let (ra, rb, rt) = (s.input(&a), s.input(&b), s.input(&tri));
                        let rc = s.buffer(&mut chain);
                        let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rc, n)?;
                        let _ = s.dtrsm(
                            Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, rt, n,
                            rc, n,
                        )?;
                        Ok(())
                    });
                    if let Err(e) = scope_res {
                        eprintln!("serve[client {client}]: scope chain failed: {e}");
                        failed.store(true, std::sync::atomic::Ordering::SeqCst);
                        return;
                    }
                    let serial = api::Context::new(devices)
                        .with_tile(t)
                        .with_persistent(false);
                    let mut want_chain = vec![0.0f64; n * n];
                    use crate::api::types::{Diag, Side, Uplo};
                    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want_chain, n)
                        .expect("serial dgemm");
                    api::trsm(&serial, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, &tri, n, &mut want_chain, n)
                        .expect("serial trsm");
                    if chain != want_chain {
                        eprintln!("serve[client {client}]: scope chain diverged from serial");
                        failed.store(true, std::sync::atomic::Ordering::SeqCst);
                    }
                }
            });
        }
    });
    if failed.load(std::sync::atomic::Ordering::SeqCst) {
        return 1;
    }
    let wall = start.elapsed().as_secs_f64();
    let busy: u64 = ctx.runtime_busy_nanos().iter().sum();
    let total_jobs = clients * jobs;
    let busy_frac = (busy.saturating_sub(busy0) as f64 / 1e9) / (wall * devices as f64);
    let flops = 2.0 * (n as f64).powi(3) * total_jobs as f64;
    println!(
        "  {total_jobs} jobs in {}: {:.1} jobs/s, {:.2} GFLOPS aggregate",
        fmt_secs(wall),
        total_jobs as f64 / wall,
        gflops(flops, wall),
    );
    println!(
        "  worker busy fraction {:.2} (idle {:.2}), runtime calls {}",
        busy_frac.min(1.0),
        (1.0 - busy_frac).max(0.0),
        ctx.runtime_calls(),
    );
    // Per-worker and per-client breakdowns from the metrics registry
    // (the same snapshot `--metrics-out` serializes), not ad-hoc
    // timers. Columns are documented in README "Observability".
    let metrics = ctx.snapshot_metrics();
    if let Some(m) = &metrics {
        if let Some(workers) = m.get("workers").and_then(Json::as_arr) {
            for w in workers {
                println!(
                    "  worker dev{}: busy {} ({:.0}% of uptime)  rounds {}",
                    w.get("dev").and_then(Json::as_usize).unwrap_or(0),
                    fmt_secs(w.get("busy_s").and_then(Json::as_f64).unwrap_or(0.0)),
                    100.0 * w.get("busy_fraction").and_then(Json::as_f64).unwrap_or(0.0),
                    w.get("rounds").and_then(Json::as_usize).unwrap_or(0),
                );
            }
        }
        if let Some(Json::Obj(tenants)) = m.get("per_tenant") {
            let q = |o: &Json, field: &str, p: &str| {
                o.get(field).and_then(|h| h.get(p)).and_then(Json::as_f64).unwrap_or(0.0)
            };
            println!("  client latency (ms): tenant jobs queue-wait p50/p95/p99 | end-to-end p50/p95/p99");
            for (tenant, o) in tenants {
                println!(
                    "    t{tenant} {} {:.2}/{:.2}/{:.2} | {:.2}/{:.2}/{:.2}",
                    o.get("jobs").and_then(Json::as_usize).unwrap_or(0),
                    q(o, "queue_wait_ms", "p50"),
                    q(o, "queue_wait_ms", "p95"),
                    q(o, "queue_wait_ms", "p99"),
                    q(o, "end_to_end_ms", "p50"),
                    q(o, "end_to_end_ms", "p95"),
                    q(o, "end_to_end_ms", "p99"),
                );
            }
            // Fault-tolerance ledger: only printed when something
            // actually happened (quiet runs stay quiet).
            let n = |o: &Json, field: &str| o.get(field).and_then(Json::as_usize).unwrap_or(0);
            let eventful: Vec<_> = tenants
                .iter()
                .filter(|(_, o)| {
                    n(o, "failed") + n(o, "rejected") + n(o, "retried") + n(o, "degraded")
                        + n(o, "migrated")
                        > 0
                })
                .collect();
            if !eventful.is_empty() {
                println!("  faults: tenant failed rejected retried degraded migrated");
                for (tenant, o) in eventful {
                    println!(
                        "    t{tenant} {} {} {} {} {}",
                        n(o, "failed"),
                        n(o, "rejected"),
                        n(o, "retried"),
                        n(o, "degraded"),
                        n(o, "migrated"),
                    );
                }
            }
        }
    }
    if let Some(path) = &trace_out {
        match ctx.chrome_trace_json() {
            Some(json) => {
                if let Err(e) = std::fs::write(path, json) {
                    eprintln!("cannot write {path}: {e}");
                    return 1;
                }
                println!("  chrome trace written to {path} (load in Perfetto / chrome://tracing)");
            }
            None => eprintln!("serve: tracing unavailable; no trace written"),
        }
    }
    if let Some(path) = &metrics_out {
        match &metrics {
            Some(m) => {
                if let Err(e) = std::fs::write(path, m.to_string_pretty()) {
                    eprintln!("cannot write {path}: {e}");
                    return 1;
                }
                println!("  metrics written to {path}");
            }
            None => eprintln!("serve: metrics unavailable; nothing written"),
        }
    }
    if let Some(mut server) = telemetry_server {
        // Give external scrapers (CI, `blasx top`) a window to land
        // after the workload drains, then take the endpoint down
        // cleanly (drop would too; this logs intent).
        let linger = args.get_usize("linger-ms", 0);
        if linger > 0 {
            println!("  telemetry endpoint lingering {linger} ms for scrapers");
            std::thread::sleep(std::time::Duration::from_millis(linger as u64));
        }
        server.stop();
    }
    0
}

/// Minimal HTTP/1.0 GET against a telemetry endpoint (stdlib only);
/// returns the response body.
fn http_get(addr: &str, path: &str) -> std::io::Result<String> {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr)?;
    s.set_read_timeout(Some(std::time::Duration::from_secs(2)))?;
    write!(s, "GET {path} HTTP/1.0\r\nHost: blasx\r\nConnection: close\r\n\r\n")?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    Ok(buf.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("").to_string())
}

/// `blasx top`: a refreshing terminal view over any `--telemetry-addr`
/// endpoint — scrape `/metrics`, parse the text format back, render
/// the fleet's live gauges. `--iters 0` (default) refreshes forever.
fn cmd_top(args: &Args) -> i32 {
    use crate::trace::prometheus;
    use std::collections::BTreeMap;

    let addr = args.get("addr").unwrap_or("127.0.0.1:9464");
    let interval = args.get_usize("interval-ms", 1000).max(50);
    let iters = args.get_usize("iters", 0);
    let mut done = 0usize;
    loop {
        let text = match http_get(addr, "/metrics") {
            Ok(t) => t,
            Err(e) => {
                eprintln!("top: cannot scrape {addr}: {e}");
                return 1;
            }
        };
        let metrics = prometheus::parse(&text);
        // Index: name → [(labels, value)] for the families we render.
        let mut by_name: BTreeMap<&str, Vec<(&[(String, String)], f64)>> = BTreeMap::new();
        for (name, labels, value) in &metrics {
            by_name.entry(name.as_str()).or_default().push((labels.as_slice(), *value));
        }
        let scalar = |name: &str| {
            by_name.get(name).and_then(|v| v.first()).map_or(0.0, |(_, val)| *val)
        };
        let by_label = |name: &str, key: &str| -> BTreeMap<String, f64> {
            by_name.get(name).map_or_else(BTreeMap::new, |v| {
                v.iter()
                    .filter_map(|(labels, val)| {
                        labels.iter().find(|(k, _)| k == key).map(|(_, lv)| (lv.clone(), *val))
                    })
                    .collect()
            })
        };
        println!(
            "blasx top — {addr}  up={}  uptime {}  [sample {}]",
            scalar("blasx_up") as u64,
            fmt_secs(scalar("blasx_uptime_seconds")),
            done + 1,
        );
        println!(
            "  jobs: queue {} (runnable {}, blocked {})  in-flight {}  admitted {}  retired {}  failed {}  rejected {}",
            scalar("blasx_queue_depth") as u64,
            scalar("blasx_jobs_runnable") as u64,
            scalar("blasx_jobs_blocked") as u64,
            scalar("blasx_jobs_in_flight") as u64,
            scalar("blasx_jobs_admitted_total") as u64,
            scalar("blasx_jobs_retired_total") as u64,
            scalar("blasx_jobs_failed_total") as u64,
            scalar("blasx_jobs_rejected_total") as u64,
        );
        let up = by_label("blasx_device_up", "dev");
        let busy = by_label("blasx_worker_busy_fraction", "dev");
        let hit = by_label("blasx_cache_hit_rate", "dev");
        let resident = by_label("blasx_cache_resident_tiles", "dev");
        let arena = by_label("blasx_arena_bytes_in_use", "dev");
        let hw = by_label("blasx_arena_high_water_bytes", "dev");
        let pf_hits = by_label("blasx_prefetch_hits_total", "dev");
        let pf_wasted = by_label("blasx_prefetch_wasted_total", "dev");
        for (dev, alive) in &up {
            println!(
                "  dev{dev}: {}  busy {:3.0}%  hit-rate {:.2}  resident {} tiles  arena {} (hw {})  prefetch {}/{} hit/wasted",
                if *alive > 0.0 { "up  " } else { "DEAD" },
                100.0 * busy.get(dev).copied().unwrap_or(0.0),
                hit.get(dev).copied().unwrap_or(0.0),
                resident.get(dev).copied().unwrap_or(0.0) as u64,
                fmt_bytes(arena.get(dev).copied().unwrap_or(0.0) as u64),
                fmt_bytes(hw.get(dev).copied().unwrap_or(0.0) as u64),
                pf_hits.get(dev).copied().unwrap_or(0.0) as u64,
                pf_wasted.get(dev).copied().unwrap_or(0.0) as u64,
            );
        }
        let inflight_xfers = scalar("blasx_inflight_transfers") as u64;
        if inflight_xfers > 0 {
            println!("  transfers in flight: {inflight_xfers}");
        }
        let tenants = by_label("blasx_tenant_inflight", "tenant");
        if !tenants.is_empty() {
            let line: Vec<String> =
                tenants.iter().map(|(t, v)| format!("t{t}={}", *v as u64)).collect();
            println!("  tenants in-flight: {}", line.join(" "));
        }
        done += 1;
        if iters > 0 && done >= iters {
            return 0;
        }
        std::thread::sleep(std::time::Duration::from_millis(interval as u64));
    }
}

/// Execute a JSON workload script through the real runtime: the
/// "launcher" path for driving BLASX from job files.
fn cmd_batch(args: &Args) -> i32 {
    use crate::api::{self, types::Trans, types::Uplo, types::Side, types::Diag};
    use crate::util::json::{self, Json};
    use crate::util::prng::Prng;
    use crate::util::stats::{fmt_secs, gflops};

    let Some(path) = args.positional.get(1) else {
        eprintln!("batch: missing workload file");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("batch: cannot read {path}: {e}");
            return 1;
        }
    };
    let spec = match json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("batch: bad JSON: {e}");
            return 1;
        }
    };
    let Some(calls) = spec.as_arr() else {
        eprintln!("batch: workload must be a JSON array of calls");
        return 1;
    };

    let devices = args.get_usize("devices", 2);
    let t = args.get_usize("t", 256);
    let mut ctx = api::Context::new(devices)
        .with_tile(t)
        .with_kernel_threads(args.get_usize("kernel-threads", 1))
        .with_persistent(args.persistent());
    if args.get("pjrt").is_some() {
        ctx = ctx.with_backend(crate::coordinator::Backend::Pjrt);
    }
    if args.get("fused").is_some() {
        return cmd_batch_fused(&ctx, calls);
    }
    let mut rng = Prng::new(7);
    let mut total_flops = 0.0;
    let start = std::time::Instant::now();
    for (i, call) in calls.iter().enumerate() {
        let routine = call.get("routine").and_then(Json::as_str).unwrap_or("dgemm");
        let Some(routine) = parse_routine(routine) else {
            eprintln!("batch[{i}]: unknown routine");
            return 1;
        };
        let n = call.get("n").and_then(Json::as_usize).unwrap_or(512);
        let m = call.get("m").and_then(Json::as_usize).unwrap_or(n);
        let k = call.get("k").and_then(Json::as_usize).unwrap_or(n);
        let mut a = vec![0.0f64; m.max(n).max(k).pow(2)];
        let mut b = a.clone();
        let mut c = vec![0.0f64; m * n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        // triangular operands need a dominant diagonal
        let na = m.max(n);
        for ii in 0..na {
            a[ii * na + ii] = 2.0 + a[ii * na + ii].abs();
        }
        // a/b are fresh same-size allocations every loop iteration —
        // declare them to the persistent runtime's cross-call cache
        // (the allocator may hand back the previous call's addresses).
        ctx.invalidate_host(&a);
        ctx.invalidate_host(&b);
        let t0 = std::time::Instant::now();
        let (flops, res) = match routine {
            Routine::Gemm => (
                2.0 * (m * n * k) as f64,
                api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m),
            ),
            Routine::Syrk => (
                (n * n * k) as f64,
                api::syrk(&ctx, Uplo::Lower, Trans::No, n, k, 1.0, &a, n, 0.0, &mut c[..n * n], n),
            ),
            Routine::Syr2k => (
                2.0 * (n * n * k) as f64,
                api::syr2k(&ctx, Uplo::Lower, Trans::No, n, k, 1.0, &a, n, &b, n, 0.0, &mut c[..n * n], n),
            ),
            Routine::Symm => (
                2.0 * (m * m * n) as f64,
                api::symm(&ctx, Side::Left, Uplo::Upper, m, n, 1.0, &a, m, &b, m, 0.0, &mut c, m),
            ),
            Routine::Trmm => (
                (m * m * n) as f64,
                api::trmm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0, &a, m, &mut c, m),
            ),
            Routine::Trsm => (
                (m * m * n) as f64,
                api::trsm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0, &a, m, &mut c, m),
            ),
        };
        if let Err(e) = res {
            eprintln!("batch[{i}] {}: {e}", routine.dname());
            return 1;
        }
        total_flops += flops;
        println!(
            "batch[{i}] {} m={m} n={n} k={k}: {}",
            routine.dname(),
            fmt_secs(t0.elapsed().as_secs_f64())
        );
    }
    let secs = start.elapsed().as_secs_f64();
    println!(
        "batch done: {} calls in {} ({:.2} GFLOPS aggregate)",
        calls.len(),
        fmt_secs(secs),
        gflops(total_flops, secs)
    );
    0
}

/// The `--fused` path: a gemm-only workload script through ONE
/// `dgemm_batched` call — the batch subsystem's throughput mode.
fn cmd_batch_fused(ctx: &crate::api::Context, calls: &[crate::util::json::Json]) -> i32 {
    use crate::api::{self, GemmBatchEntry};
    use crate::util::json::Json;
    use crate::util::prng::Prng;
    use crate::util::stats::{fmt_secs, gflops};

    let mut entries = Vec::with_capacity(calls.len());
    for (i, call) in calls.iter().enumerate() {
        let routine = call.get("routine").and_then(Json::as_str).unwrap_or("dgemm");
        if parse_routine(routine) != Some(crate::api::types::Routine::Gemm) {
            eprintln!("batch[{i}]: --fused supports gemm calls only (got {routine}); drop --fused to loop mixed workloads");
            return 1;
        }
        let n = call.get("n").and_then(Json::as_usize).unwrap_or(512);
        let m = call.get("m").and_then(Json::as_usize).unwrap_or(n);
        let k = call.get("k").and_then(Json::as_usize).unwrap_or(n);
        entries.push(GemmBatchEntry::new(m, n, k, 1.0, 0.0));
    }

    let mut rng = Prng::new(7);
    let mut abufs = Vec::with_capacity(entries.len());
    let mut bbufs = Vec::with_capacity(entries.len());
    let mut cbufs = Vec::with_capacity(entries.len());
    let mut total_flops = 0.0;
    for e in &entries {
        let mut a = vec![0.0f64; e.m * e.k];
        let mut b = vec![0.0f64; e.k * e.n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        abufs.push(a);
        bbufs.push(b);
        cbufs.push(vec![0.0f64; e.m * e.n]);
        total_flops += 2.0 * (e.m * e.n * e.k) as f64;
    }
    let arefs: Vec<&[f64]> = abufs.iter().map(Vec::as_slice).collect();
    let brefs: Vec<&[f64]> = bbufs.iter().map(Vec::as_slice).collect();
    let mut crefs: Vec<&mut [f64]> = cbufs.iter_mut().map(Vec::as_mut_slice).collect();

    let start = std::time::Instant::now();
    let rep = match api::dgemm_batched(ctx, &entries, &arefs, &brefs, &mut crefs) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("batch --fused: {e}");
            return 1;
        }
    };
    let secs = start.elapsed().as_secs_f64();
    println!(
        "batch --fused: {} problems in {} ({:.2} GFLOPS aggregate, one scheduler invocation)",
        entries.len(),
        fmt_secs(secs),
        gflops(total_flops, secs)
    );
    println!(
        "  tasks/device {:?}  steals {:?}  cache this-call {:?}",
        rep.tasks_per_device, rep.steals, rep.cache_delta
    );
    0
}

fn cmd_sim(args: &Args, want_gantt: bool) -> i32 {
    let routine = parse_routine(args.get("routine").unwrap_or("dgemm")).unwrap_or(Routine::Gemm);
    let n = args.get_usize("n", 8192);
    let t = args.get_usize("t", 1024);
    let gpus = args.get_usize("gpus", 3);
    let machine = parse_machine(args.get("machine").unwrap_or("everest"), gpus);
    let policy = Policy::from_name(args.get("policy").unwrap_or("blasx")).unwrap_or(Policy::Blasx);
    let dtype = if args.get("routine").unwrap_or("d").starts_with('s') { Dtype::F32 } else { Dtype::F64 };

    let mut cfg = RunConfig { t, policy, ..Default::default() };
    cfg.use_cpu = args.get("cpu").is_some();
    cfg.work_stealing = args.get("no-steal").is_none();

    let w = square_workload(routine, n, t, dtype);
    let rep = run_sim(&cfg, &machine, &w);
    if !rep.feasible {
        println!("{}: INFEASIBLE (policy cannot run this size)", policy.name());
        return 1;
    }
    println!(
        "{} {} N={n} T={t} on {}×{} [{}]",
        policy.name(),
        routine.dname(),
        machine.devices.len(),
        machine.devices[0].name,
        machine.name,
    );
    println!(
        "  makespan {}   {:.0} GFLOPS   tasks/worker {:?}   steals {:?}",
        fmt_secs(rep.makespan),
        gflops(w.total_flops(), rep.makespan),
        rep.tasks_per_worker,
        rep.steals,
    );
    for (d, p) in all_profiles(&rep.trace).iter().enumerate() {
        println!(
            "  dev{d}: COMPT {}  COMM {}  OTHER {}",
            fmt_secs(p.compt),
            fmt_secs(p.comm),
            fmt_secs(p.other)
        );
    }
    for (d, v) in comm_volumes(&rep.trace).iter().enumerate() {
        println!(
            "  dev{d}: H<->D {}  P2P {}",
            fmt_bytes(v.hd_bytes as u64),
            fmt_bytes(v.p2p_bytes as u64)
        );
    }
    let (hd, pp) = rep.dma_throughput;
    println!("  DMA: H<->D {}/s  P2P {}/s", fmt_bytes(hd as u64), fmt_bytes(pp as u64));
    if want_gantt {
        let width = args.get_usize("width", 100);
        print!("{}", gantt::render(&rep.trace, width));
        if let Some(path) = args.get("json") {
            match std::fs::write(path, gantt::to_json(&rep.trace).to_string_pretty()) {
                Ok(()) => println!("trace written to {path}"),
                Err(e) => eprintln!("cannot write {path}: {e}"),
            }
        }
    }
    0
}

fn cmd_run(args: &Args) -> i32 {
    use crate::api::{self, types::Trans};
    use crate::util::prng::Prng;

    let n = args.get_usize("n", 1024);
    let t = args.get_usize("t", 256);
    let devices = args.get_usize("devices", 2);
    let repeat = args.get_usize("repeat", 1).max(1);
    let trace_out = args.get("trace-out").map(str::to_string);
    let metrics_out = args.get("metrics-out").map(str::to_string);
    let mut ctx = api::Context::new(devices)
        .with_tile(t)
        .with_kernel_threads(args.get_usize("kernel-threads", 1))
        .with_persistent(args.persistent());
    if args.get("pjrt").is_some() {
        ctx = ctx.with_backend(crate::coordinator::Backend::Pjrt);
    }
    if let Some(depth) = args.get("prefetch") {
        match depth.parse::<usize>() {
            Ok(d) => ctx = ctx.with_prefetch(Some(d)),
            Err(_) => {
                eprintln!("run: --prefetch wants a tile count, got {depth:?}");
                return 2;
            }
        }
    }
    if let Some(path) = args.get("profile") {
        ctx = match ctx.with_profile_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("run: {e}");
                return 2;
            }
        };
    } else if args.get("adaptive").is_some() {
        ctx = ctx.with_adaptive_dispatch();
    }
    if trace_out.is_some() {
        if ctx.persistent {
            ctx.set_tracing(true);
        } else {
            eprintln!("run: --trace-out requires the persistent runtime; ignoring");
        }
    }

    let mut p = Prng::new(2015);
    let mut a = vec![0.0f64; n * n];
    let mut b = vec![0.0f64; n * n];
    let mut c = vec![0.0f64; n * n];
    p.fill_f64(&mut a, -1.0, 1.0);
    p.fill_f64(&mut b, -1.0, 1.0);
    p.fill_f64(&mut c, -1.0, 1.0);

    let flops = 2.0 * (n as f64).powi(3);
    println!(
        "DGEMM N={n} T={t} devices={devices} runtime={}",
        if ctx.persistent { "persistent" } else { "one-shot" }
    );
    for call in 0..repeat {
        let start = std::time::Instant::now();
        // beta = 0 so C is never host-read: a fully warm repeat shows
        // (0, 0, 0) host reads, matching the usage text's claim.
        let rep = match api::dgemm(
            &ctx, Trans::No, Trans::No, n, n, n, 1.5, &a, n, &b, n, 0.0, &mut c, n,
        ) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        let secs = start.elapsed().as_secs_f64();
        println!(
            "  call {call}: {} wall, {:.2} GFLOPS  host-reads (A,B,C) {:?}  peer {}  L1 hits {}  prefetch {}/{} hit/wasted",
            fmt_secs(secs),
            gflops(flops, secs),
            rep.transfers.host_reads,
            rep.transfers.peer_copies,
            rep.transfers.l1_hits,
            rep.transfers.prefetch_hits,
            rep.transfers.prefetch_wasted,
        );
        if call + 1 == repeat {
            println!(
                "  tasks/device {:?}  cache this-call {:?}  cumulative {:?}",
                rep.tasks_per_device, rep.cache_delta, rep.cache_stats
            );
        }
    }
    if ctx.tracing_enabled() {
        // The paper's Fig. 8 / Table V splits, from real wall-clock
        // spans instead of the discrete-event simulator.
        if let Some(trace) = ctx.snapshot_trace() {
            for (d, p) in all_profiles(&trace).iter().enumerate() {
                println!(
                    "  dev{d}: COMPT {}  COMM {}  OTHER {}",
                    fmt_secs(p.compt),
                    fmt_secs(p.comm),
                    fmt_secs(p.other)
                );
            }
            for (d, v) in comm_volumes(&trace).iter().enumerate() {
                println!(
                    "  dev{d}: H<->D {}  P2P {}",
                    fmt_bytes(v.hd_bytes as u64),
                    fmt_bytes(v.p2p_bytes as u64)
                );
            }
            let ov = crate::trace::overlap_report(&trace);
            println!(
                "  comm hidden under compute: {:.0}% ({} of {} comm)",
                100.0 * ov.hidden_frac(),
                fmt_secs(ov.comm_hidden),
                fmt_secs(ov.comm_total),
            );
        }
        if let (Some(path), Some(json)) = (&trace_out, ctx.chrome_trace_json()) {
            match std::fs::write(path, json) {
                Ok(()) => println!("  chrome trace written to {path} (load in Perfetto / chrome://tracing)"),
                Err(e) => {
                    eprintln!("cannot write {path}: {e}");
                    return 1;
                }
            }
        }
    }
    if let Some(path) = &metrics_out {
        match ctx.snapshot_metrics() {
            Some(m) => {
                if let Err(e) = std::fs::write(path, m.to_string_pretty()) {
                    eprintln!("cannot write {path}: {e}");
                    return 1;
                }
                println!("  metrics written to {path}");
            }
            None => eprintln!("run: --metrics-out requires the persistent runtime; ignoring"),
        }
    }
    println!("  verification: see `cargo test` for the full oracle grid");
    0
}

fn cmd_info() -> i32 {
    match crate::runtime::ArtifactStore::open_default() {
        Ok(s) => {
            let mut names: Vec<&str> = s.variants().collect();
            names.sort_unstable();
            println!(
                "artifacts: {} variants × tiles {:?} × dtypes {:?}",
                names.len(),
                s.tile_sizes,
                s.dtypes.iter().map(|d| d.name()).collect::<Vec<_>>()
            );
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    for m in [everest(3), makalu(4)] {
        println!("machine {}: ", m.name);
        for d in &m.devices {
            println!(
                "  {} dp {:.0} GF/s sp {:.0} GF/s vram {}",
                d.name,
                d.dp_gflops,
                d.sp_gflops,
                fmt_bytes(d.vram as u64)
            );
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse_args(&sv(&["sim", "--n", "4096", "--policy=magma", "--cpu"]));
        assert_eq!(a.positional, vec!["sim"]);
        assert_eq!(a.get("n"), Some("4096"));
        assert_eq!(a.get("policy"), Some("magma"));
        assert_eq!(a.get("cpu"), Some("true"));
        assert_eq!(a.get_usize("n", 0), 4096);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn routine_parsing() {
        assert_eq!(parse_routine("dgemm"), Some(Routine::Gemm));
        assert_eq!(parse_routine("ssyr2k"), Some(Routine::Syr2k));
        assert_eq!(parse_routine("nope"), None);
    }

    #[test]
    fn sim_command_small() {
        // exercise the full sim command path on a tiny problem
        let rc = dispatch(&sv(&["sim", "--n", "1024", "--t", "256", "--machine", "everest", "--gpus", "2"]));
        assert_eq!(rc, 0);
    }

    #[test]
    fn usage_on_unknown() {
        assert_eq!(dispatch(&sv(&["bogus"])), 2);
    }

    #[test]
    fn batch_runs_workload_script() {
        let path = std::env::temp_dir().join(format!("blasx_batch_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"[{"routine": "dgemm", "n": 64}, {"routine": "dsyrk", "n": 64, "k": 48}]"#,
        )
        .unwrap();
        let rc = dispatch(&sv(&["batch", path.to_str().unwrap(), "--t", "32", "--devices", "2"]));
        std::fs::remove_file(&path).unwrap();
        assert_eq!(rc, 0);
    }

    #[test]
    fn batch_fused_runs_gemm_script() {
        let path = std::env::temp_dir().join(format!("blasx_fused_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"[{"routine": "dgemm", "n": 64}, {"routine": "dgemm", "n": 48, "m": 33, "k": 17}]"#,
        )
        .unwrap();
        let rc = dispatch(&sv(&["batch", path.to_str().unwrap(), "--t", "32", "--fused"]));
        std::fs::remove_file(&path).unwrap();
        assert_eq!(rc, 0);
    }

    #[test]
    fn batch_fused_rejects_non_gemm() {
        let path = std::env::temp_dir().join(format!("blasx_fusedbad_{}.json", std::process::id()));
        std::fs::write(&path, r#"[{"routine": "dtrsm", "n": 64}]"#).unwrap();
        let rc = dispatch(&sv(&["batch", path.to_str().unwrap(), "--fused"]));
        std::fs::remove_file(&path).unwrap();
        assert_eq!(rc, 1);
    }

    #[test]
    fn persistent_flag_parsing() {
        assert!(parse_args(&sv(&["run"])).persistent(), "default on");
        assert!(!parse_args(&sv(&["run", "--no-persistent"])).persistent());
        assert!(!parse_args(&sv(&["run", "--persistent=false"])).persistent());
        assert!(!parse_args(&sv(&["run", "--persistent", "off"])).persistent());
        assert!(parse_args(&sv(&["run", "--persistent"])).persistent());
    }

    #[test]
    fn run_repeat_exercises_warm_calls() {
        // Two calls through one warm context (and the one-shot escape
        // hatch) both complete through the CLI.
        let rc = dispatch(&sv(&["run", "--n", "96", "--t", "32", "--repeat", "2"]));
        assert_eq!(rc, 0);
        let rc = dispatch(&sv(&["run", "--n", "64", "--t", "32", "--no-persistent"]));
        assert_eq!(rc, 0);
    }

    #[test]
    fn serve_stress_mode_smoke() {
        // 3 clients × 2 jobs of a tiny DGEMM through the multi-tenant
        // scheduler, with oracle verification of each client's result.
        let rc = dispatch(&sv(&[
            "serve", "--clients", "3", "--jobs", "2", "--n", "64", "--t", "32", "--verify",
        ]));
        assert_eq!(rc, 0);
    }

    #[test]
    fn serve_chaos_smoke() {
        // Chaos armed: the last device dies early, transient faults hit
        // dev0 — every client's result must still verify against the
        // host oracle (recovery is correctness-preserving).
        let rc = dispatch(&sv(&[
            "serve", "--clients", "2", "--jobs", "2", "--n", "96", "--t", "32", "--devices",
            "2", "--chaos", "--verify",
        ]));
        assert_eq!(rc, 0);
    }

    #[test]
    fn serve_rejects_bad_faults_spec() {
        let rc = dispatch(&sv(&["serve", "--faults", "explode@dev0:op1"]));
        assert_eq!(rc, 2);
    }

    #[test]
    fn serve_with_telemetry_endpoint_smoke() {
        // Port 0 = ephemeral bind; the endpoint serves fresh scrapes
        // during the run and shuts down with the command.
        let rc = dispatch(&sv(&[
            "serve", "--clients", "2", "--jobs", "1", "--n", "64", "--t", "32",
            "--telemetry-addr", "127.0.0.1:0",
        ]));
        assert_eq!(rc, 0);
    }

    #[test]
    fn top_reports_unreachable_endpoint() {
        // Nothing listens on the reserved port 1: top must fail fast
        // with a scrape error, not hang.
        assert_eq!(dispatch(&sv(&["top", "--addr", "127.0.0.1:1", "--iters", "1"])), 1);
    }

    #[test]
    fn batch_rejects_missing_file() {
        assert_eq!(dispatch(&sv(&["batch", "/nonexistent/x.json"])), 1);
        assert_eq!(dispatch(&sv(&["batch"])), 2);
    }

    #[test]
    fn header_prints_and_writes() {
        assert_eq!(dispatch(&sv(&["header"])), 0);
        let path = std::env::temp_dir().join(format!("blasx_h_{}.h", std::process::id()));
        assert_eq!(dispatch(&sv(&["header", "--out", path.to_str().unwrap()])), 0);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(text, crate::ffi::header::render());
    }

    #[test]
    fn tune_writes_a_profile_that_run_and_serve_consume() {
        // End-to-end satellite check: a tiny sweep → profile on disk →
        // `run --profile` and `serve --profile --verify` both succeed
        // under dispatched tile sizes.
        let path = std::env::temp_dir().join(format!("blasx_prof_{}.json", std::process::id()));
        let p = path.to_str().unwrap();
        let rc = dispatch(&sv(&[
            "tune", "--quick", "--devices", "1", "--shapes", "96", "--small-shapes", "48",
            "--reps", "1", "--out", p,
        ]));
        assert_eq!(rc, 0);
        let prof = crate::dispatch::Profile::load(p).unwrap();
        assert!(!prof.is_empty(), "tune must record entries");
        assert_eq!(dispatch(&sv(&["run", "--n", "96", "--t", "64", "--profile", p])), 0);
        let rc = dispatch(&sv(&[
            "serve", "--clients", "2", "--jobs", "1", "--n", "96", "--t", "64", "--profile", p,
            "--verify",
        ]));
        std::fs::remove_file(&path).unwrap();
        assert_eq!(rc, 0);
    }

    #[test]
    fn run_rejects_missing_profile() {
        assert_eq!(dispatch(&sv(&["run", "--profile", "/nonexistent/p.json"])), 2);
        assert_eq!(dispatch(&sv(&["serve", "--profile", "/nonexistent/p.json"])), 2);
    }

    #[test]
    fn tune_rejects_bad_shape_list() {
        assert_eq!(dispatch(&sv(&["tune", "--shapes", "96,banana"])), 2);
    }

    #[test]
    fn run_adaptive_smoke() {
        assert_eq!(dispatch(&sv(&["run", "--n", "96", "--t", "64", "--adaptive", "--repeat", "2"])), 0);
    }

    #[test]
    fn serve_ffi_verify_passes() {
        // The drop-in acceptance check: C entry points bit-for-bit
        // against the safe path, including the aliasing async chain.
        assert_eq!(dispatch(&sv(&["serve", "--ffi-verify"])), 0);
    }
}
