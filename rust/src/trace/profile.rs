//! Per-device execution profile: the paper's Fig. 8 dissection into
//! COMPT (kernel time), COMM (unoverlapped communication) and OTHER
//! (sync latency + idle gaps between launches), plus the Table V
//! communication-volume split and the Fig. 8 load-balance gap metric.

use super::events::{uncovered_len, union_len, EvKind, Trace};

/// The Fig. 8 triple for one device, in seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeviceProfile {
    pub compt: f64,
    pub comm: f64,
    pub other: f64,
    /// Device elapsed = COMPT + COMM + OTHER (first to last activity,
    /// extended to the run makespan — idle tails are OTHER).
    pub elapsed: f64,
}

/// Table V row for one device, in bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommVolume {
    /// Bidirectional host↔device bytes (the table's black figures).
    pub hd_bytes: f64,
    /// P2P bytes received (the table's red figures).
    pub p2p_bytes: f64,
}

/// Profile of one device from its trace events.
pub fn device_profile(trace: &Trace, dev: usize) -> DeviceProfile {
    let mut kern: Vec<(f64, f64)> = Vec::new();
    let mut comm: Vec<(f64, f64)> = Vec::new();
    for e in trace.of_device(dev) {
        match e.kind {
            EvKind::Kernel => kern.push((e.start, e.end)),
            _ => comm.push((e.start, e.end)),
        }
    }
    if kern.is_empty() && comm.is_empty() {
        return DeviceProfile { elapsed: trace.makespan, other: trace.makespan, ..Default::default() };
    }
    let compt = union_len(&mut kern.clone());
    let comm_unoverlapped = uncovered_len(&mut comm, &mut kern);
    let elapsed = trace.makespan;
    DeviceProfile {
        compt,
        comm: comm_unoverlapped,
        other: (elapsed - compt - comm_unoverlapped).max(0.0),
        elapsed,
    }
}

/// Profiles for every device.
pub fn all_profiles(trace: &Trace) -> Vec<DeviceProfile> {
    (0..trace.n_devices()).map(|d| device_profile(trace, d)).collect()
}

/// Table V communication volumes for every device.
pub fn comm_volumes(trace: &Trace) -> Vec<CommVolume> {
    (0..trace.n_devices())
        .map(|d| CommVolume {
            hd_bytes: trace.bytes(d, EvKind::H2d) + trace.bytes(d, EvKind::D2h),
            p2p_bytes: trace.bytes(d, EvKind::P2p),
        })
        .collect()
}

/// Comm/compute overlap of one device, in seconds: how much of its
/// communication window ran concurrently with kernels.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeviceOverlap {
    /// Union length of this device's comm intervals (H2D + D2H + P2P).
    pub comm: f64,
    /// Comm time covered by this device's *own* kernels (nonzero only
    /// when a device truly double-buffers: a transfer lane moving bytes
    /// while the same device computes).
    pub hidden_local: f64,
    /// Comm time covered by kernels running concurrently on *any*
    /// device — the machine-level "communication hidden under
    /// computation" of the paper's overlap claim.
    pub hidden_global: f64,
}

/// The paper's Fig. 8 made quantitative: the fraction of communication
/// time hidden under concurrently executing kernels.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OverlapReport {
    pub per_device: Vec<DeviceOverlap>,
    /// Σ over devices of the per-device comm unions.
    pub comm_total: f64,
    /// Σ over devices of `hidden_global`.
    pub comm_hidden: f64,
}

impl OverlapReport {
    /// The headline number: comm-hidden-under-compute fraction in
    /// `[0, 1]` (0 when the trace moved no bytes).
    pub fn hidden_frac(&self) -> f64 {
        if self.comm_total > 0.0 {
            (self.comm_hidden / self.comm_total).clamp(0.0, 1.0)
        } else {
            0.0
        }
    }
}

/// Quantify comm/compute overlap from a (wall-clock or simulated)
/// trace: for each device, how much of its communication-interval
/// union is covered by its own kernels (`hidden_local`) and by kernels
/// anywhere on the machine (`hidden_global`). Degraded host-fallback
/// copies never reach the `Trace` (`SpanKind::HostFallback` has no
/// `EvKind`), so they cannot inflate these numbers.
pub fn overlap_report(trace: &Trace) -> OverlapReport {
    let n = trace.n_devices();
    let mut all_kern: Vec<(f64, f64)> = Vec::new();
    let mut per_comm: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n];
    let mut per_kern: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n];
    for (d, (comm, kern)) in per_comm.iter_mut().zip(per_kern.iter_mut()).enumerate() {
        for e in trace.of_device(d) {
            match e.kind {
                EvKind::Kernel => {
                    kern.push((e.start, e.end));
                    all_kern.push((e.start, e.end));
                }
                _ => comm.push((e.start, e.end)),
            }
        }
    }
    let mut report = OverlapReport::default();
    for d in 0..n {
        let comm = union_len(&mut per_comm[d].clone());
        let uncovered_local = uncovered_len(&mut per_comm[d].clone(), &mut per_kern[d].clone());
        let uncovered_global = uncovered_len(&mut per_comm[d].clone(), &mut all_kern.clone());
        let dd = DeviceOverlap {
            comm,
            hidden_local: (comm - uncovered_local).max(0.0),
            hidden_global: (comm - uncovered_global).max(0.0),
        };
        report.comm_total += dd.comm;
        report.comm_hidden += dd.hidden_global;
        report.per_device.push(dd);
    }
    report
}

/// The paper's load-balance gap: elapsed-time difference between the
/// busiest and least-busy device (using COMPT+COMM as "busy").
pub fn balance_gap(trace: &Trace) -> f64 {
    let profs = all_profiles(trace);
    if profs.is_empty() {
        return 0.0;
    }
    let busy: Vec<f64> = profs.iter().map(|p| p.compt + p.comm).collect();
    let max = busy.iter().cloned().fold(f64::MIN, f64::max);
    let min = busy.iter().cloned().fold(f64::MAX, f64::min);
    (max - min).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_trace() -> Trace {
        let mut t = Trace::new();
        // dev0: kernel [0,2), transfer [1,3) -> 1s overlapped, 1s not
        t.record(0, 0, EvKind::Kernel, 0.0, 2.0, 1e9);
        t.record(0, 1, EvKind::H2d, 1.0, 3.0, 8e6);
        // dev1: only transfers
        t.record(1, 0, EvKind::P2p, 0.0, 1.0, 4e6);
        t.makespan = 4.0;
        t
    }

    #[test]
    fn fig8_classification() {
        let t = mk_trace();
        let p0 = device_profile(&t, 0);
        assert_eq!(p0.compt, 2.0);
        assert_eq!(p0.comm, 1.0);
        assert_eq!(p0.other, 1.0); // 4.0 makespan - 3.0 busy
        assert_eq!(p0.elapsed, 4.0);
        let p1 = device_profile(&t, 1);
        assert_eq!(p1.compt, 0.0);
        assert_eq!(p1.comm, 1.0);
        assert_eq!(p1.other, 3.0);
    }

    #[test]
    fn table5_volumes() {
        let t = mk_trace();
        let v = comm_volumes(&t);
        assert_eq!(v[0].hd_bytes, 8e6);
        assert_eq!(v[0].p2p_bytes, 0.0);
        assert_eq!(v[1].p2p_bytes, 4e6);
    }

    #[test]
    fn overlap_fractions_local_vs_global() {
        let t = mk_trace();
        let r = overlap_report(&t);
        // dev0: comm [1,3) (2s), own kernel [0,2) covers [1,2) → 1s local
        assert_eq!(r.per_device[0].comm, 2.0);
        assert_eq!(r.per_device[0].hidden_local, 1.0);
        assert_eq!(r.per_device[0].hidden_global, 1.0);
        // dev1: comm [0,1), no own kernels, but dev0's kernel [0,2)
        // covers it entirely → machine-level overlap
        assert_eq!(r.per_device[1].comm, 1.0);
        assert_eq!(r.per_device[1].hidden_local, 0.0);
        assert_eq!(r.per_device[1].hidden_global, 1.0);
        assert_eq!(r.comm_total, 3.0);
        assert_eq!(r.comm_hidden, 2.0);
        assert!((r.hidden_frac() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn overlap_of_empty_trace_is_zero() {
        let r = overlap_report(&Trace::new());
        assert_eq!(r.comm_total, 0.0);
        assert_eq!(r.hidden_frac(), 0.0);
        assert!(r.per_device.is_empty());
    }

    #[test]
    fn gap_metric() {
        let t = mk_trace();
        // busy: dev0 = 3.0, dev1 = 1.0
        assert_eq!(balance_gap(&t), 2.0);
    }

    #[test]
    fn empty_device_is_all_other() {
        let mut t = mk_trace();
        t.record(2, 0, EvKind::Kernel, 0.0, 0.0, 0.0); // zero-length
        let p = device_profile(&t, 2);
        assert_eq!(p.compt, 0.0);
        assert!(p.other > 3.9);
    }
}
