//! ASCII gantt rendering of a trace — the Fig. 1 "execution profile
//! snapshot" as a terminal chart, one row per (device, stream/DMA lane).

use super::events::{EvKind, Trace};

/// Render `trace` as an ASCII gantt of `width` columns. Rows: per device
/// one kernel row per stream plus one row per transfer class. Glyphs:
/// `#` kernel, `>` H2D, `<` D2H, `=` P2P.
pub fn render(trace: &Trace, width: usize) -> String {
    let mut out = String::new();
    if trace.events.is_empty() || trace.makespan <= 0.0 {
        return "(empty trace)\n".to_string();
    }
    let scale = width as f64 / trace.makespan;
    let n_dev = trace.n_devices();
    for dev in 0..n_dev {
        let streams = trace
            .of_device(dev)
            .filter(|e| e.kind == EvKind::Kernel)
            .map(|e| e.stream + 1)
            .max()
            .unwrap_or(0);
        for s in 0..streams {
            let mut row = vec![b'.'; width];
            for e in trace.of_device(dev).filter(|e| e.kind == EvKind::Kernel && e.stream == s) {
                paint(&mut row, e.start, e.end, scale, b'#');
            }
            out.push_str(&format!("gpu{dev} s{s} |{}|\n", String::from_utf8_lossy(&row)));
        }
        for (kind, glyph, label) in
            [(EvKind::H2d, b'>', "h2d"), (EvKind::D2h, b'<', "d2h"), (EvKind::P2p, b'=', "p2p")]
        {
            let evs: Vec<_> = trace.of_device(dev).filter(|e| e.kind == kind).collect();
            if evs.is_empty() {
                continue;
            }
            let mut row = vec![b'.'; width];
            for e in evs {
                paint(&mut row, e.start, e.end, scale, glyph);
            }
            out.push_str(&format!("gpu{dev} {label} |{}|\n", String::from_utf8_lossy(&row)));
        }
    }
    out.push_str(&format!("scale: {} = {:.4}s\n", width, trace.makespan));
    out
}

/// Serialize a trace to JSON (one object per event) for external
/// replotting — the machine-readable twin of [`render`].
pub fn to_json(trace: &Trace) -> crate::util::json::Json {
    use crate::util::json::Json;
    let mut root = Json::obj();
    root.set("makespan", Json::Num(trace.makespan));
    let events: Vec<Json> = trace
        .events
        .iter()
        .map(|e| {
            let mut o = Json::obj();
            o.set("dev", Json::Num(e.dev as f64));
            o.set("stream", Json::Num(e.stream as f64));
            o.set(
                "kind",
                Json::Str(
                    match e.kind {
                        EvKind::Kernel => "kernel",
                        EvKind::H2d => "h2d",
                        EvKind::D2h => "d2h",
                        EvKind::P2p => "p2p",
                    }
                    .to_string(),
                ),
            );
            o.set("start", Json::Num(e.start));
            o.set("end", Json::Num(e.end));
            o.set("amount", Json::Num(e.amount));
            o
        })
        .collect();
    root.set("events", Json::Arr(events));
    root
}

fn paint(row: &mut [u8], start: f64, end: f64, scale: f64, glyph: u8) {
    let w = row.len();
    let a = ((start * scale) as usize).min(w.saturating_sub(1));
    let b = ((end * scale).ceil() as usize).clamp(a + 1, w);
    for c in row.iter_mut().take(b).skip(a) {
        *c = glyph;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_rows_per_stream_and_lane() {
        let mut t = Trace::new();
        t.record(0, 0, EvKind::Kernel, 0.0, 0.5, 1.0);
        t.record(0, 1, EvKind::Kernel, 0.5, 1.0, 1.0);
        t.record(0, 0, EvKind::H2d, 0.0, 0.25, 8.0);
        t.record(1, 0, EvKind::P2p, 0.0, 1.0, 8.0);
        t.makespan = 1.0;
        let g = render(&t, 40);
        assert!(g.contains("gpu0 s0 |"));
        assert!(g.contains("gpu0 s1 |"));
        assert!(g.contains("gpu0 h2d"));
        assert!(g.contains("gpu1 p2p"));
        assert!(g.contains('#'));
        assert!(g.contains('>'));
        assert!(g.contains('='));
        // first half of s0 painted, second half idle
        let s0 = g.lines().find(|l| l.starts_with("gpu0 s0")).unwrap();
        assert!(s0.contains("#."));
    }

    #[test]
    fn empty_trace() {
        assert_eq!(render(&Trace::new(), 10), "(empty trace)\n");
    }
}
