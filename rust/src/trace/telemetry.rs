//! Live telemetry plane: periodically sampled runtime gauges.
//!
//! PR 6's metrics are pull-based post-mortems — you read a snapshot
//! after a call returns. This module adds the *live* half: a
//! low-priority sampler thread (owned by the resident `Runtime`) that
//! every `BLASX_TELEMETRY_MS` milliseconds (default 100 when enabled;
//! unset or `0` = **off**, the default) snapshots cheap gauges into a
//! fixed-capacity ring:
//!
//! - per-device arena bytes in use / high watermark (FastHeap stats)
//! - ALRU occupancy and a *windowed* hit rate (delta between
//!   consecutive samples, not lifetime average)
//! - admission-table depth, runnable/blocked job counts
//! - per-tenant in-flight and global backpressure counters
//! - worker busy fraction and rounds
//! - dispatcher online-EWMA state (shapes tracked / observations)
//!
//! ## Zero-cost-when-off contract
//!
//! When the sampler is off (the default) **no thread is spawned and no
//! allocation happens** — `Telemetry::new` with `interval_ms == 0`
//! builds empty vectors (capacity 0) and `Runtime::boot` skips the
//! spawn entirely. `rust/tests/telemetry.rs` pins this with the
//! counting allocator. When on, each sample allocates a few small
//! `Vec`s; the ring is bounded at [`TELEMETRY_RING`] samples so a
//! long-running serve holds constant memory.
//!
//! The *gathering* of a sample lives in `runtime/service.rs`
//! (`Runtime::telemetry_now`) because it needs the table / caches /
//! metrics locks; this module owns the data shape, the ring, and the
//! sampler lifecycle primitives.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Samples retained in the history ring (60 s at the default 100 ms).
pub const TELEMETRY_RING: usize = 600;

/// Default sampling interval when telemetry is enabled without an
/// explicit period.
pub const DEFAULT_INTERVAL_MS: u64 = 100;

/// Per-device gauge block within one sample.
#[derive(Clone, Debug, Default)]
pub struct DevGauges {
    pub dev: usize,
    /// Device is dead per the fault plane (PR 7 ledger).
    pub dead: bool,
    /// FastHeap bytes currently allocated.
    pub arena_in_use: usize,
    /// FastHeap lifetime high watermark.
    pub arena_high_water: usize,
    /// Tiles resident in the ALRU.
    pub cache_resident: usize,
    /// Cumulative cache counters (for rate computation downstream).
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    /// Hit rate over the window since the previous sample
    /// (`NaN`-free: 0.0 when the window saw no lookups).
    pub hit_rate: f64,
    /// Cumulative demand acquires served by a prefetched tile.
    pub prefetch_hits: u64,
    /// Cumulative prefetched tiles dropped unconsumed (TTL expiry,
    /// invalidation, or pressure flush).
    pub prefetch_wasted: u64,
    /// Cumulative busy nanoseconds for this device's worker.
    pub busy_nanos: u64,
    /// Busy fraction over the window since the previous sample.
    pub busy_fraction: f64,
    /// Cumulative scheduling rounds executed by this worker.
    pub rounds: u64,
}

/// One telemetry sample: everything the exporter needs, gathered at a
/// single instant.
#[derive(Clone, Debug, Default)]
pub struct TelemetrySample {
    /// Seconds since runtime boot.
    pub t_s: f64,
    pub devices: Vec<DevGauges>,
    /// Jobs occupying admission-table slots (live, any state).
    pub queue_depth: usize,
    /// Jobs with no unmet dependency edges.
    pub runnable: usize,
    /// Jobs blocked on dependency edges.
    pub blocked: usize,
    /// Jobs admitted and not yet retired.
    pub in_flight: usize,
    /// Tile transfers (fills, preloads, write-backs) in flight off the
    /// cache lock at the sampling instant.
    pub inflight_transfers: usize,
    /// Cumulative admission counters.
    pub admitted: u64,
    pub retired: u64,
    pub failed: u64,
    /// Backpressure rejections (bounded admission, tenant quota).
    pub rejected: u64,
    /// `(tenant, in_flight)` for tenants with live jobs.
    pub per_tenant: Vec<(u32, usize)>,
    /// Dispatcher online state: `(shape buckets tracked, observations)`
    /// — `(0, 0)` when no adaptive dispatcher is attached.
    pub dispatch_shapes: usize,
    pub dispatch_observations: u64,
}

/// Sampler state: history ring plus the stop latch the background
/// thread parks on (condvar so `Drop for Runtime` can wake it
/// immediately instead of waiting out the interval).
pub struct Telemetry {
    interval_ms: u64,
    ring: Mutex<VecDeque<TelemetrySample>>,
    stop: Mutex<bool>,
    stop_cv: Condvar,
}

impl Telemetry {
    /// `interval_ms == 0` builds a disabled, allocation-free shell
    /// (`enabled()` false, ring capacity 0).
    pub fn new(interval_ms: u64) -> Telemetry {
        Telemetry {
            interval_ms,
            ring: Mutex::new(VecDeque::with_capacity(if interval_ms == 0 {
                0
            } else {
                TELEMETRY_RING
            })),
            stop: Mutex::new(false),
            stop_cv: Condvar::new(),
        }
    }

    /// Resolve the sampling interval: a programmatic override wins,
    /// else `BLASX_TELEMETRY_MS` (unset or `0` = off; set but
    /// unparseable = the default interval, honoring intent to enable).
    pub fn interval_from_env(override_ms: Option<u64>) -> u64 {
        if let Some(ms) = override_ms {
            return ms;
        }
        match std::env::var("BLASX_TELEMETRY_MS") {
            Err(_) => 0,
            Ok(s) => match s.trim().parse::<u64>() {
                Ok(ms) => ms,
                Err(_) => DEFAULT_INTERVAL_MS,
            },
        }
    }

    /// Is the sampler configured to run?
    pub fn enabled(&self) -> bool {
        self.interval_ms > 0
    }

    pub fn interval_ms(&self) -> u64 {
        self.interval_ms
    }

    /// Append a sample, evicting the oldest once the ring is full.
    pub fn push(&self, s: TelemetrySample) {
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        if ring.len() >= TELEMETRY_RING {
            ring.pop_front();
        }
        ring.push_back(s);
    }

    /// Samples retained (oldest first).
    pub fn history(&self) -> Vec<TelemetrySample> {
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).iter().cloned().collect()
    }

    /// Most recent sample, if any.
    pub fn latest(&self) -> Option<TelemetrySample> {
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).back().cloned()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Park the sampler thread for one interval; returns `false` when
    /// the runtime asked it to stop (wake is immediate via condvar).
    pub fn park_interval(&self) -> bool {
        let stop = self.stop.lock().unwrap_or_else(|p| p.into_inner());
        let (stop, _timeout) = self
            .stop_cv
            .wait_timeout_while(stop, Duration::from_millis(self.interval_ms.max(1)), |s| !*s)
            .unwrap_or_else(|p| p.into_inner());
        !*stop
    }

    /// Tell the sampler thread to exit and wake it now.
    pub fn request_stop(&self) {
        *self.stop.lock().unwrap_or_else(|p| p.into_inner()) = true;
        self.stop_cv.notify_all();
    }
}

/// Fill the windowed rates on `cur` from the previous sample (if any).
/// Windowed hit rate and busy fraction come from deltas between
/// consecutive cumulative counters — a lifetime average hides a cold
/// cache turning hot (or a hot one being invalidated).
pub fn fill_windowed_rates(cur: &mut TelemetrySample, prev: Option<&TelemetrySample>) {
    let Some(prev) = prev else {
        for d in &mut cur.devices {
            let total = d.cache_hits + d.cache_misses;
            d.hit_rate = if total == 0 { 0.0 } else { d.cache_hits as f64 / total as f64 };
        }
        return;
    };
    let dt_s = (cur.t_s - prev.t_s).max(0.0);
    for d in &mut cur.devices {
        let p = prev.devices.iter().find(|p| p.dev == d.dev);
        let (ph, pm, pb) = p.map_or((0, 0, 0), |p| (p.cache_hits, p.cache_misses, p.busy_nanos));
        let dh = d.cache_hits.saturating_sub(ph);
        let dm = d.cache_misses.saturating_sub(pm);
        let lookups = dh + dm;
        d.hit_rate = if lookups == 0 { 0.0 } else { dh as f64 / lookups as f64 };
        let dbusy = d.busy_nanos.saturating_sub(pb) as f64 / 1e9;
        d.busy_fraction = if dt_s > 0.0 { (dbusy / dt_s).clamp(0.0, 1.0) } else { 0.0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_shell_holds_no_capacity() {
        let t = Telemetry::new(0);
        assert!(!t.enabled());
        assert!(t.is_empty());
        assert_eq!(t.ring.lock().unwrap().capacity(), 0);
    }

    #[test]
    fn ring_is_bounded() {
        let t = Telemetry::new(5);
        for i in 0..(TELEMETRY_RING + 50) {
            t.push(TelemetrySample { t_s: i as f64, ..Default::default() });
        }
        assert_eq!(t.len(), TELEMETRY_RING);
        let hist = t.history();
        // Oldest samples were evicted.
        assert_eq!(hist[0].t_s, 50.0);
        assert_eq!(t.latest().unwrap().t_s, (TELEMETRY_RING + 49) as f64);
    }

    #[test]
    fn windowed_rates_use_deltas() {
        let mut prev = TelemetrySample { t_s: 1.0, ..Default::default() };
        prev.devices.push(DevGauges {
            dev: 0,
            cache_hits: 100,
            cache_misses: 100,
            busy_nanos: 0,
            ..Default::default()
        });
        let mut cur = TelemetrySample { t_s: 2.0, ..Default::default() };
        cur.devices.push(DevGauges {
            dev: 0,
            cache_hits: 200, // +100 hits
            cache_misses: 100, // +0 misses
            busy_nanos: 500_000_000, // 0.5 s busy over a 1 s window
            ..Default::default()
        });
        fill_windowed_rates(&mut cur, Some(&prev));
        assert_eq!(cur.devices[0].hit_rate, 1.0, "window was all hits");
        assert!((cur.devices[0].busy_fraction - 0.5).abs() < 1e-9);
    }

    #[test]
    fn first_sample_falls_back_to_lifetime_rate() {
        let mut cur = TelemetrySample { t_s: 1.0, ..Default::default() };
        cur.devices.push(DevGauges {
            dev: 0,
            cache_hits: 3,
            cache_misses: 1,
            ..Default::default()
        });
        fill_windowed_rates(&mut cur, None);
        assert!((cur.devices[0].hit_rate - 0.75).abs() < 1e-9);
    }

    #[test]
    fn park_returns_false_after_stop() {
        let t = std::sync::Arc::new(Telemetry::new(10_000));
        let t2 = t.clone();
        let h = std::thread::spawn(move || t2.park_interval());
        std::thread::sleep(Duration::from_millis(20));
        t.request_stop();
        assert!(!h.join().unwrap(), "stop must wake the parked sampler");
    }

    #[test]
    fn env_resolution_precedence() {
        // Programmatic override wins regardless of env.
        assert_eq!(Telemetry::interval_from_env(Some(25)), 25);
        assert_eq!(Telemetry::interval_from_env(Some(0)), 0);
        // NOTE: env-var cases are covered in tests/telemetry.rs where
        // the process env can be controlled before runtime boot.
    }
}
