//! Wall-clock span recorder for the **real** execution path.
//!
//! The sim engine books virtual time straight into [`crate::trace::Trace`],
//! which is what the paper-figure analyses (`device_profile`,
//! `comm_volumes`, `balance_gap`) consume. The resident runtime was
//! blind by comparison: one `RealReport` of counters per call, no
//! timeline. The [`Recorder`] closes that gap — device workers emit
//! timed [`Span`]s for kernels, tile movement, pack work, scheduler
//! rounds, steal retries and condvar parks, and the recorder converts
//! the subset matching the sim-era [`EvKind`] taxonomy into a `Trace`
//! with **real timestamps**, so Fig. 8's COMPT/COMM/OTHER split and
//! Table V's H↔D vs P2P volumes run unchanged against wall-clock data.
//!
//! ## Overhead contract
//!
//! The recorder is owned by the [`crate::coordinator::real_engine::EngineCore`]
//! and sits on the hot path of every tile acquire and kernel dispatch,
//! so the *disabled* path must cost nothing measurable: one relaxed
//! atomic load per probe, no clock read, no allocation
//! (`rust/tests/observability.rs` pins the no-allocation property with
//! a counting allocator, and `benches/call_overhead.rs` compares warm
//! call latency with the recorder on vs off). Enabled, spans go to
//! per-device shards (one mutex each — a device's spans are recorded
//! by its own worker thread, so sharded pushes never contend).
//!
//! Enable with `BLASX_TRACE=1` in the environment (read at core
//! construction) or programmatically via
//! [`crate::api::Context::set_tracing`] / `blasx run --trace-out`.

use super::events::{EvKind, Trace};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// What a recorded interval was spent on. The first four variants are
/// the sim-era [`EvKind`] taxonomy (they flow into [`Trace`] and the
/// paper-figure analyses); the rest are runtime-internal phases that
/// only the Chrome export and the span tests see.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Kernel execution (COMPT). `amount` = flops.
    Kernel,
    /// Host→arena tile read (the engine's DMA analogue; includes the
    /// strided gather out of the user's matrix). `amount` = bytes.
    H2d,
    /// Arena→host write-back of a C tile. `amount` = bytes.
    D2h,
    /// Arena→arena peer copy (L2 hit). `amount` = bytes.
    P2p,
    /// Tile staging that moves no host bytes: zero-fill of edge/non-
    /// accumulating C blocks, identity-padding of diagonal tiles.
    Pack,
    /// One scheduler round (refill → bind → execute → sync) that made
    /// progress. `amount` = flops charged to the fair-share ledger.
    Round,
    /// A work-steal attempt on a dry station. `amount` = 1.0 if a task
    /// was stolen, 0.0 if the probe came up empty.
    Steal,
    /// The worker was parked on the idle condvar.
    Park,
    /// A fault fired from the injection plane (device kill, wedge, or a
    /// forced op failure). `amount` = the faulted device index.
    Fault,
    /// A faulted or refused operation being retried (transient kernel/
    /// transfer failure, arena-OOM eviction-retry backoff). `amount` =
    /// the attempt number.
    Retry,
    /// A task abandoned on a dead device and re-admitted, or drained
    /// from a dead device's station by a survivor. `amount` = task id.
    Migrate,
    /// One lookahead-prefetch pass of the asynchronous transfer
    /// pipeline (the individual copies it issues are recorded with
    /// their true kinds, `H2d`/`P2p`, so the Fig. 8 / Table V analyses
    /// see them; this span is the pass envelope). `amount` = bytes
    /// prefetched.
    Prefetch,
    /// A private host-side operand copy on the degradation ladder
    /// (arena OOM after bounded retries, or a transfer-fault fallback).
    /// Deliberately distinct from `H2d`: no arena DMA happened, so
    /// these bytes must NOT inflate the COMM fraction or the Table V
    /// transfer volumes. `amount` = bytes copied.
    HostFallback,
}

impl SpanKind {
    /// The sim-era event kind this span maps onto, if any.
    pub fn ev(self) -> Option<EvKind> {
        match self {
            SpanKind::Kernel => Some(EvKind::Kernel),
            SpanKind::H2d => Some(EvKind::H2d),
            SpanKind::D2h => Some(EvKind::D2h),
            SpanKind::P2p => Some(EvKind::P2p),
            _ => None,
        }
    }
}

/// One timed interval on one device worker. Timestamps are seconds
/// since the recorder's epoch (core construction), captured with a
/// monotonic clock.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub dev: usize,
    pub kind: SpanKind,
    pub start: f64,
    pub end: f64,
    /// Bytes (transfers), flops (kernels/rounds), or a flag (steals).
    pub amount: f64,
    /// Admission id of the owning job; 0 for the one-shot engine and
    /// for spans outside any job (parks).
    pub job: u64,
}

/// Admission→first-round→retire lifecycle of one job, recorded when
/// the job retires. Feeds the per-job tracks of the Chrome export.
#[derive(Clone, Debug)]
pub struct JobRec {
    pub job: u64,
    pub tenant: u32,
    pub routine: &'static str,
    /// Seconds since the recorder epoch.
    pub admit: f64,
    /// First scheduler round that picked the job (== `retire` if the
    /// job retired without running, e.g. cancelled while still queued
    /// behind its dependency edges).
    pub first_round: f64,
    pub retire: f64,
    pub failed: bool,
}

/// Low-overhead wall-clock span recorder (see module docs).
pub struct Recorder {
    enabled: AtomicBool,
    epoch: Instant,
    /// One shard per device: a device's spans are pushed by its own
    /// worker thread, so these mutexes are uncontended in steady state
    /// (snapshot readers are the only cross-thread lockers).
    shards: Vec<Mutex<Vec<Span>>>,
    jobs: Mutex<Vec<JobRec>>,
}

impl Recorder {
    /// A recorder for `n_devices` workers, initially enabled iff the
    /// `BLASX_TRACE` environment variable is truthy.
    pub fn new(n_devices: usize) -> Recorder {
        let env_on = matches!(
            std::env::var("BLASX_TRACE").ok().as_deref().map(str::trim),
            Some("1") | Some("true") | Some("on") | Some("yes")
        );
        Recorder {
            enabled: AtomicBool::new(env_on),
            epoch: Instant::now(),
            shards: (0..n_devices.max(1)).map(|_| Mutex::new(Vec::new())).collect(),
            jobs: Mutex::new(Vec::new()),
        }
    }

    /// Is the recorder capturing spans? One relaxed load — this is the
    /// entire cost of every probe on the disabled path.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Start (or stop) capturing. Previously captured spans are kept;
    /// call [`Recorder::reset`] to drop them.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Seconds since the recorder epoch — `0.0` when disabled, so the
    /// disabled path never reads the clock.
    #[inline]
    pub fn now(&self) -> f64 {
        if !self.is_enabled() {
            return 0.0;
        }
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record one span. `start` must come from [`Recorder::now`] taken
    /// while enabled; if the recorder was disabled when the span
    /// opened (start == 0.0 sentinel with a disabled flag now), the
    /// span is dropped rather than recorded with a bogus start.
    #[inline]
    pub fn record(&self, dev: usize, kind: SpanKind, start: f64, amount: f64, job: u64) {
        if !self.is_enabled() {
            return;
        }
        let end = self.epoch.elapsed().as_secs_f64();
        // A span opened before `set_enabled(true)` has a zero start
        // but a large end; clamp instead of dropping so the first
        // enabled round is not lost (starts are still monotone).
        let start = if start <= 0.0 { end } else { start.min(end) };
        let shard = dev.min(self.shards.len() - 1);
        let mut spans = self.shards[shard].lock().unwrap_or_else(|e| e.into_inner());
        spans.push(Span { dev, kind, start, end, amount, job });
    }

    /// Record one job's lifecycle (called by the resident worker that
    /// retires it).
    pub fn record_job(&self, rec: JobRec) {
        if !self.is_enabled() {
            return;
        }
        self.jobs.lock().unwrap_or_else(|e| e.into_inner()).push(rec);
    }

    /// Snapshot every span captured so far (all shards, unsorted
    /// across devices; per-device order is record order).
    pub fn spans(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.extend(shard.lock().unwrap_or_else(|e| e.into_inner()).iter().copied());
        }
        out
    }

    /// Snapshot the retired-job lifecycles captured so far.
    pub fn job_records(&self) -> Vec<JobRec> {
        self.jobs.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Drop every captured span and job record (enabled state is
    /// unchanged).
    pub fn reset(&self) {
        for shard in &self.shards {
            shard.lock().unwrap_or_else(|e| e.into_inner()).clear();
        }
        self.jobs.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }

    /// Convert the captured spans into a sim-compatible [`Trace`] with
    /// real timestamps: only the [`EvKind`] subset flows in (kernels
    /// and tile movement), timestamps are shifted so the first event
    /// starts at 0, and the makespan is the active window — exactly
    /// the shape `device_profile` / `comm_volumes` / `balance_gap`
    /// expect, so the paper's Fig. 8 / Table V analyses run unchanged
    /// on wall-clock data.
    pub fn to_trace(&self) -> Trace {
        let spans = self.spans();
        let mut trace = Trace::new();
        let t0 = spans
            .iter()
            .filter(|s| s.kind.ev().is_some())
            .map(|s| s.start)
            .fold(f64::INFINITY, f64::min);
        if !t0.is_finite() {
            return trace;
        }
        for s in &spans {
            if let Some(kind) = s.kind.ev() {
                trace.record(s.dev, 0, kind, s.start - t0, s.end - t0, s.amount);
                trace.makespan = trace.makespan.max(s.end - t0);
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::profile::{comm_volumes, device_profile};

    fn enabled_recorder(n: usize) -> Recorder {
        let r = Recorder::new(n);
        r.set_enabled(true);
        r
    }

    #[test]
    fn disabled_recorder_drops_everything() {
        let r = Recorder::new(2);
        r.set_enabled(false);
        r.record(0, SpanKind::Kernel, 0.0, 1.0, 0);
        r.record_job(JobRec {
            job: 1,
            tenant: 0,
            routine: "gemm",
            admit: 0.0,
            first_round: 0.0,
            retire: 0.0,
            failed: false,
        });
        assert!(r.spans().is_empty());
        assert!(r.job_records().is_empty());
        assert_eq!(r.now(), 0.0, "disabled probe must not read the clock");
    }

    #[test]
    fn spans_flow_into_a_profileable_trace() {
        let r = enabled_recorder(2);
        let t0 = r.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        r.record(0, SpanKind::Kernel, t0, 1000.0, 7);
        let t1 = r.now();
        r.record(1, SpanKind::H2d, t1, 4096.0, 7);
        r.record(1, SpanKind::Park, t1, 0.0, 0); // non-EvKind: excluded
        let trace = r.to_trace();
        assert_eq!(trace.events.len(), 2, "only EvKind spans flow into the Trace");
        assert!(trace.makespan > 0.0);
        assert!(trace.events.iter().all(|e| e.start >= 0.0 && e.end >= e.start));
        let p = device_profile(&trace, 0);
        assert!(p.compt > 0.0, "kernel span must surface as COMPT");
        let vols = comm_volumes(&trace);
        assert_eq!(vols[1].hd_bytes, 4096.0);
    }

    #[test]
    fn host_fallback_and_prefetch_stay_out_of_comm_analyses() {
        // Regression: the degraded host-fallback copy used to be
        // recorded as H2d, inflating the Fig. 8 COMM fraction and the
        // Table V transfer volumes with bytes that never crossed an
        // arena boundary. The distinct kinds must not map to an EvKind.
        assert_eq!(SpanKind::HostFallback.ev(), None);
        assert_eq!(SpanKind::Prefetch.ev(), None);
        let r = enabled_recorder(1);
        let t0 = r.now();
        r.record(0, SpanKind::H2d, t0, 1024.0, 1);
        r.record(0, SpanKind::HostFallback, t0, 4096.0, 1);
        r.record(0, SpanKind::Prefetch, t0, 2048.0, 1);
        let trace = r.to_trace();
        assert_eq!(trace.events.len(), 1, "only the true H2d flows into the Trace");
        let vols = comm_volumes(&trace);
        assert_eq!(vols[0].hd_bytes, 1024.0, "fallback/prefetch-envelope bytes excluded");
    }

    #[test]
    fn reset_clears_but_keeps_enabled() {
        let r = enabled_recorder(1);
        let t = r.now();
        r.record(0, SpanKind::Round, t, 1.0, 1);
        assert_eq!(r.spans().len(), 1);
        r.reset();
        assert!(r.spans().is_empty());
        assert!(r.is_enabled());
    }

    #[test]
    fn empty_recorder_yields_empty_trace() {
        let r = enabled_recorder(1);
        let t = r.to_trace();
        assert!(t.events.is_empty());
        assert_eq!(t.makespan, 0.0);
    }
}
