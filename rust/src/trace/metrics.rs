//! Per-tenant / per-routine runtime metrics: counters and latency
//! histograms over the resident runtime's job lifecycle.
//!
//! The registry is owned by the resident [`crate::runtime::Runtime`]
//! and fed by its admission path and device workers:
//!
//! - **admit** — opens a live record stamped with the submitting
//!   tenant (one id per submitting thread, assigned on first use) and
//!   the routine label carried by the call's `RunConfig`;
//! - **first round** — closes the *queue-wait* window (admission →
//!   first scheduler round that picked the job);
//! - **retire** — closes the *end-to-end* window and folds both
//!   latencies into per-(tenant, routine) histograms.
//!
//! Worker busy time is accounted here too (nanoseconds inside
//! scheduler rounds, per device), so `blasx serve`'s busy/idle line
//! and `benches/serve_throughput.rs` read one source of truth instead
//! of ad-hoc timers.
//!
//! [`MetricsRegistry::snapshot`] renders everything as a
//! [`Json`] object (schema documented in the README's Observability
//! section; validated by CI).

use crate::coordinator::FaultStats;
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

// --- tenants ---------------------------------------------------------

static NEXT_TENANT: AtomicU32 = AtomicU32::new(1);

thread_local! {
    static TENANT: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
}

/// The calling thread's tenant id (assigned on first use). A *tenant*
/// is a submitting thread: every client thread of a serving daemon —
/// or C thread entering through the FFI — gets its own latency
/// aggregates.
pub fn tenant_id() -> u32 {
    TENANT.with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_TENANT.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        v
    })
}

// --- latency histogram -----------------------------------------------

/// Buckets per octave (factor-of-two range) — bucket boundaries grow
/// by 2^(1/8) ≈ 9.05%, which bounds the relative quantile error.
const BUCKETS_PER_OCTAVE: usize = 8;
/// Smallest resolvable latency (seconds): 1 ns.
const V_MIN: f64 = 1e-9;
/// 40 octaves above 1 ns ≈ 1100 s — everything slower saturates the
/// last bucket.
const N_BUCKETS: usize = 40 * BUCKETS_PER_OCTAVE;

/// Log-bucketed latency histogram: fixed 320-bucket footprint,
/// quantiles within ~9% relative error (one bucket width), exact
/// count/sum/min/max.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u32>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: vec![0; N_BUCKETS], count: 0, sum: 0.0, min: f64::INFINITY, max: 0.0 }
    }

    fn bucket_of(v: f64) -> usize {
        if v <= V_MIN {
            return 0;
        }
        let idx = ((v / V_MIN).log2() * BUCKETS_PER_OCTAVE as f64).floor() as isize;
        idx.clamp(0, N_BUCKETS as isize - 1) as usize
    }

    /// Lower bound of bucket `i` in seconds.
    fn bucket_lo(i: usize) -> f64 {
        V_MIN * (i as f64 / BUCKETS_PER_OCTAVE as f64).exp2()
    }

    /// Record one latency sample (seconds; negatives clamp to 0).
    pub fn record(&mut self, v: f64) {
        let v = v.max(0.0);
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// The `pct`-th percentile (0..=100), linearly interpolated inside
    /// the containing bucket and clamped to the exact observed
    /// [min, max]. 0.0 for an empty histogram.
    pub fn percentile(&self, pct: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        // Same rank convention as util::stats::percentile_sorted:
        // rank 0 = min sample, rank count-1 = max sample.
        let rank = (pct / 100.0).clamp(0.0, 1.0) * (self.count - 1) as f64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let hi_rank = (seen + c as u64) as f64 - 1.0;
            if rank <= hi_rank {
                let lo = Self::bucket_lo(i);
                let hi = Self::bucket_lo(i + 1);
                let within = if c > 1 { (rank - seen as f64) / (c - 1) as f64 } else { 0.5 };
                return (lo + within * (hi - lo)).clamp(self.min, self.max);
            }
            seen += c as u64;
        }
        self.max
    }

    /// p50/p95/p99 as a JSON object in milliseconds.
    fn quantiles_ms(&self) -> Json {
        let mut o = Json::obj();
        o.set("p50", Json::Num(self.percentile(50.0) * 1e3))
            .set("p95", Json::Num(self.percentile(95.0) * 1e3))
            .set("p99", Json::Num(self.percentile(99.0) * 1e3))
            .set("mean", Json::Num(self.mean() * 1e3))
            .set("count", Json::Num(self.count as f64));
        o
    }
}

// --- registry --------------------------------------------------------

/// A job in flight: admitted but not yet retired.
struct LiveJob {
    tenant: u32,
    routine: &'static str,
    flops: f64,
    admit: Instant,
    /// Seconds from the recorder epoch (for span export) — carried
    /// through so job tracks line up with device tracks.
    admit_s: f64,
    first_round: Option<Instant>,
    first_round_s: f64,
}

/// Aggregates of one (tenant, routine) group.
#[derive(Default)]
struct GroupStats {
    jobs: u64,
    failed: u64,
    /// Admissions refused with a backpressure error (capacity or
    /// tenant quota) — these never became jobs.
    rejected: u64,
    /// Fault-recovery work done on this group's behalf: operations
    /// retried after transient faults/arena pressure, operands served
    /// through the host-path OOM fallback, tasks migrated off dead
    /// devices.
    retried: u64,
    degraded: u64,
    migrated: u64,
    flops: f64,
    queue_wait: Histogram,
    end_to_end: Histogram,
}

/// One-lock counter snapshot for the telemetry sampler (see
/// [`MetricsRegistry::job_gauges`]).
#[derive(Clone, Debug, Default)]
pub struct JobGauges {
    pub admitted: u64,
    pub retired: u64,
    pub failed: u64,
    pub rejected: u64,
    pub in_flight: usize,
    /// `(tenant, live jobs)` for tenants with work in flight.
    pub per_tenant_inflight: Vec<(u32, usize)>,
}

/// A retired job's lifecycle, handed back to the caller so the worker
/// can forward it to the span recorder without the registry holding
/// two locks.
pub struct RetiredJob {
    pub tenant: u32,
    pub routine: &'static str,
    pub admit_s: f64,
    pub first_round_s: f64,
    pub retire_s: f64,
}

#[derive(Default)]
struct Inner {
    live: HashMap<u64, LiveJob>,
    groups: BTreeMap<(u32, &'static str), GroupStats>,
    admitted: u64,
    retired: u64,
    failed: u64,
    rejected: u64,
}

/// The resident runtime's metrics registry (see module docs).
pub struct MetricsRegistry {
    booted: Instant,
    /// Per-device nanoseconds spent inside scheduler rounds.
    busy_nanos: Vec<AtomicU64>,
    /// Per-device scheduler rounds that made progress.
    rounds: Vec<AtomicU64>,
    inner: Mutex<Inner>,
}

impl MetricsRegistry {
    pub fn new(n_devices: usize) -> MetricsRegistry {
        MetricsRegistry {
            booted: Instant::now(),
            busy_nanos: (0..n_devices).map(|_| AtomicU64::new(0)).collect(),
            rounds: (0..n_devices).map(|_| AtomicU64::new(0)).collect(),
            inner: Mutex::new(Inner::default()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// A job was admitted. `now_s` is the span-recorder clock (0.0
    /// when tracing is off — only used for track alignment).
    pub fn on_admit(&self, job: u64, tenant: u32, routine: &'static str, flops: f64, now_s: f64) {
        let mut inner = self.lock();
        inner.admitted += 1;
        inner.live.insert(
            job,
            LiveJob {
                tenant,
                routine,
                flops,
                admit: Instant::now(),
                admit_s: now_s,
                first_round: None,
                first_round_s: now_s,
            },
        );
    }

    /// A device worker started a scheduler round of `job`. Cheap after
    /// the first call per job (one map probe under the mutex).
    pub fn on_round_start(&self, job: u64, now_s: f64) {
        let mut inner = self.lock();
        if let Some(live) = inner.live.get_mut(&job) {
            if live.first_round.is_none() {
                live.first_round = Some(Instant::now());
                live.first_round_s = now_s;
            }
        }
    }

    /// A round finished on `dev` after `nanos` inside the scheduler.
    pub fn on_round_end(&self, dev: usize, nanos: u64) {
        if dev < self.busy_nanos.len() {
            self.busy_nanos[dev].fetch_add(nanos, Ordering::Relaxed);
            self.rounds[dev].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// An admission was refused with a backpressure error (queue at
    /// capacity or tenant over its in-flight quota). The call never
    /// became a job — only the rejection counters move.
    pub fn on_reject(&self, tenant: u32, routine: &'static str) {
        let mut inner = self.lock();
        inner.rejected += 1;
        inner.groups.entry((tenant, routine)).or_default().rejected += 1;
    }

    /// A job retired: fold its latencies and fault-recovery counters
    /// into the aggregates and hand back the lifecycle for the span
    /// recorder.
    pub fn on_retire(
        &self,
        job: u64,
        failed: bool,
        now_s: f64,
        faults: &FaultStats,
    ) -> Option<RetiredJob> {
        let mut inner = self.lock();
        let live = inner.live.remove(&job)?;
        inner.retired += 1;
        if failed {
            inner.failed += 1;
        }
        let end_to_end = live.admit.elapsed().as_secs_f64();
        let queue_wait = match live.first_round {
            Some(first) => (end_to_end - first.elapsed().as_secs_f64()).max(0.0),
            // Retired without ever running — e.g. cancelled or reaped
            // while still queued behind dependency edges.
            None => end_to_end,
        };
        let g = inner.groups.entry((live.tenant, live.routine)).or_default();
        g.jobs += 1;
        if failed {
            g.failed += 1;
        }
        g.retried += faults.retried as u64;
        g.degraded += faults.degraded as u64;
        g.migrated += faults.migrated as u64;
        g.flops += live.flops;
        g.queue_wait.record(queue_wait);
        g.end_to_end.record(end_to_end);
        Some(RetiredJob {
            tenant: live.tenant,
            routine: live.routine,
            admit_s: live.admit_s,
            first_round_s: if live.first_round.is_some() { live.first_round_s } else { now_s },
            retire_s: now_s,
        })
    }

    /// Cumulative per-device busy nanoseconds since boot.
    pub fn busy_nanos(&self) -> Vec<u64> {
        self.busy_nanos.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }

    /// Cumulative per-device scheduler rounds since boot.
    pub fn rounds(&self) -> Vec<u64> {
        self.rounds.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }

    /// One-lock gauge read for the telemetry sampler: cumulative job
    /// counters plus the per-tenant in-flight breakdown, without
    /// building the full JSON snapshot every tick.
    pub fn job_gauges(&self) -> JobGauges {
        let inner = self.lock();
        let mut per_tenant: BTreeMap<u32, usize> = BTreeMap::new();
        for live in inner.live.values() {
            *per_tenant.entry(live.tenant).or_insert(0) += 1;
        }
        JobGauges {
            admitted: inner.admitted,
            retired: inner.retired,
            failed: inner.failed,
            rejected: inner.rejected,
            in_flight: inner.live.len(),
            per_tenant_inflight: per_tenant.into_iter().collect(),
        }
    }

    /// Seconds since the registry (== runtime) booted.
    pub fn uptime(&self) -> f64 {
        self.booted.elapsed().as_secs_f64()
    }

    /// Render the registry as JSON: global job counters, per-worker
    /// busy/idle fractions against runtime uptime, and per-tenant /
    /// per-routine latency quantiles (milliseconds).
    pub fn snapshot(&self) -> Json {
        let wall = self.uptime().max(1e-9);
        let inner = self.lock();
        let mut workers = Vec::new();
        for (dev, busy) in self.busy_nanos.iter().enumerate() {
            let busy_s = busy.load(Ordering::Relaxed) as f64 / 1e9;
            let mut w = Json::obj();
            w.set("dev", Json::Num(dev as f64))
                .set("busy_s", Json::Num(busy_s))
                .set("busy_fraction", Json::Num((busy_s / wall).min(1.0)))
                .set("rounds", Json::Num(self.rounds[dev].load(Ordering::Relaxed) as f64));
            workers.push(w);
        }
        // Roll the (tenant, routine) groups up both ways.
        #[derive(Default)]
        struct Roll {
            jobs: u64,
            failed: u64,
            rejected: u64,
            retried: u64,
            degraded: u64,
            migrated: u64,
            flops: f64,
            queue_wait: Histogram,
            end_to_end: Histogram,
        }
        impl Roll {
            fn fold(&mut self, g: &GroupStats) {
                self.jobs += g.jobs;
                self.failed += g.failed;
                self.rejected += g.rejected;
                self.retried += g.retried;
                self.degraded += g.degraded;
                self.migrated += g.migrated;
                self.flops += g.flops;
                merge(&mut self.queue_wait, &g.queue_wait);
                merge(&mut self.end_to_end, &g.end_to_end);
            }
            fn json(&self, with_flops: bool) -> Json {
                let mut o = Json::obj();
                o.set("jobs", Json::Num(self.jobs as f64))
                    .set("failed", Json::Num(self.failed as f64))
                    .set("rejected", Json::Num(self.rejected as f64))
                    .set("retried", Json::Num(self.retried as f64))
                    .set("degraded", Json::Num(self.degraded as f64))
                    .set("migrated", Json::Num(self.migrated as f64))
                    .set("queue_wait_ms", self.queue_wait.quantiles_ms())
                    .set("end_to_end_ms", self.end_to_end.quantiles_ms());
                if with_flops {
                    o.set("flops", Json::Num(self.flops));
                }
                o
            }
        }
        let mut tenants: BTreeMap<u32, Roll> = BTreeMap::new();
        let mut routines: BTreeMap<&'static str, Roll> = BTreeMap::new();
        for (&(tenant, routine), g) in &inner.groups {
            tenants.entry(tenant).or_default().fold(g);
            routines.entry(routine).or_default().fold(g);
        }
        let mut per_tenant = Json::obj();
        for (tenant, roll) in &tenants {
            per_tenant.set(&format!("{tenant}"), roll.json(false));
        }
        let mut per_routine = Json::obj();
        for (routine, roll) in &routines {
            per_routine.set(routine, roll.json(true));
        }
        let mut out = Json::obj();
        out.set("uptime_s", Json::Num(wall))
            .set("jobs_admitted", Json::Num(inner.admitted as f64))
            .set("jobs_retired", Json::Num(inner.retired as f64))
            .set("jobs_failed", Json::Num(inner.failed as f64))
            .set("jobs_rejected", Json::Num(inner.rejected as f64))
            .set("jobs_in_flight", Json::Num(inner.live.len() as f64))
            .set("workers", Json::Arr(workers))
            .set("per_tenant", per_tenant)
            .set("per_routine", per_routine);
        out
    }
}

/// Merge `src` into `dst` (bucket-wise — both share the fixed layout).
fn merge(dst: &mut Histogram, src: &Histogram) {
    for (d, s) in dst.counts.iter_mut().zip(&src.counts) {
        *d += s;
    }
    dst.count += src.count;
    dst.sum += src.sum;
    dst.min = dst.min.min(src.min);
    dst.max = dst.max.max(src.max);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_ids_are_stable_per_thread_and_distinct_across() {
        let mine = tenant_id();
        assert_eq!(tenant_id(), mine);
        let other = std::thread::spawn(tenant_id).join().unwrap();
        assert_ne!(mine, other);
    }

    #[test]
    fn histogram_percentiles_track_the_samples() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3); // 1ms .. 100ms
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(50.0);
        assert!((p50 - 0.050).abs() / 0.050 < 0.10, "p50 {p50}");
        let p99 = h.percentile(99.0);
        assert!((p99 - 0.099).abs() / 0.099 < 0.10, "p99 {p99}");
        assert!(h.percentile(0.0) >= 1e-3 * 0.9);
        assert!(h.percentile(100.0) <= 0.1);
        assert!((h.mean() - 0.0505).abs() < 1e-4);
    }

    #[test]
    fn histogram_empty_and_single() {
        let h = Histogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        let mut h = Histogram::new();
        h.record(0.25);
        // A single sample answers every quantile with (about) itself.
        assert!((h.percentile(1.0) - 0.25).abs() / 0.25 < 0.10);
        assert!((h.percentile(99.0) - 0.25).abs() / 0.25 < 0.10);
    }

    #[test]
    fn registry_lifecycle_folds_into_groups() {
        let reg = MetricsRegistry::new(2);
        reg.on_admit(1, 3, "gemm", 100.0, 0.0);
        reg.on_round_start(1, 0.1);
        reg.on_round_start(1, 0.2); // second round: first-round stamp holds
        reg.on_round_end(0, 5_000_000);
        let none = FaultStats::default();
        let retired = reg.on_retire(1, false, 0.3, &none).expect("live job retires");
        assert_eq!(retired.tenant, 3);
        assert_eq!(retired.routine, "gemm");
        assert!(reg.on_retire(1, false, 0.4, &none).is_none(), "double retire is ignored");
        let snap = reg.snapshot();
        assert_eq!(snap.get("jobs_retired").and_then(Json::as_f64), Some(1.0));
        assert_eq!(snap.get("jobs_in_flight").and_then(Json::as_f64), Some(0.0));
        let routines = snap.get("per_routine").expect("per_routine");
        let gemm = routines.get("gemm").expect("gemm group");
        assert_eq!(gemm.get("jobs").and_then(Json::as_f64), Some(1.0));
        assert!(gemm.get("end_to_end_ms").and_then(|q| q.get("p50")).is_some());
        let workers = snap.get("workers").and_then(Json::as_arr).expect("workers");
        assert_eq!(workers.len(), 2);
        assert!(workers[0].get("busy_s").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn rejections_and_fault_counters_surface_per_tenant() {
        let reg = MetricsRegistry::new(1);
        reg.on_reject(5, "gemm");
        reg.on_reject(5, "gemm");
        reg.on_admit(1, 5, "gemm", 10.0, 0.0);
        let faults = FaultStats { retried: 3, degraded: 1, migrated: 2 };
        reg.on_retire(1, true, 0.1, &faults).expect("retires");
        let snap = reg.snapshot();
        assert_eq!(snap.get("jobs_rejected").and_then(Json::as_f64), Some(2.0));
        assert_eq!(snap.get("jobs_failed").and_then(Json::as_f64), Some(1.0));
        let tenant = snap.get("per_tenant").and_then(|t| t.get("5")).expect("tenant 5");
        assert_eq!(tenant.get("rejected").and_then(Json::as_f64), Some(2.0));
        assert_eq!(tenant.get("failed").and_then(Json::as_f64), Some(1.0));
        assert_eq!(tenant.get("retried").and_then(Json::as_f64), Some(3.0));
        assert_eq!(tenant.get("degraded").and_then(Json::as_f64), Some(1.0));
        assert_eq!(tenant.get("migrated").and_then(Json::as_f64), Some(2.0));
    }
}
