//! Prometheus text-format exporter + stdlib-only scrape endpoint.
//!
//! [`render`] turns one [`TelemetrySample`] into Prometheus text
//! exposition format 0.0.4 (`# HELP` / `# TYPE` per family, labels in
//! `{}`), and [`TelemetryServer`] serves it over a bare
//! [`std::net::TcpListener`] — no HTTP crate, because the protocol
//! surface we need is one request line and two routes:
//!
//! - `GET /metrics` — the gauge catalog below, gathered fresh at
//!   scrape time (works even with the background sampler off);
//! - `GET /healthz` — `200 ok` while every device is alive, `503`
//!   naming the dead devices per PR 7's fault ledger. The death state
//!   comes from the same [`EngineCore::dead_devices`] source the
//!   metrics snapshot uses — one source of truth, pinned by a
//!   regression test.
//!
//! `blasx serve --telemetry-addr 127.0.0.1:9464` starts one; `blasx
//! top` and `tools/check_prometheus.py` scrape it.
//!
//! [`EngineCore::dead_devices`]: crate::coordinator::real_engine::EngineCore::dead_devices

use super::telemetry::TelemetrySample;
use crate::api::Context;
use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Render one sample as Prometheus text exposition format 0.0.4.
pub fn render(s: &TelemetrySample) -> String {
    let mut out = String::with_capacity(4096);
    let mut family = |name: &str, help: &str, kind: &str| {
        out.push_str("# HELP blasx_");
        out.push_str(name);
        out.push(' ');
        out.push_str(help);
        out.push_str("\n# TYPE blasx_");
        out.push_str(name);
        out.push(' ');
        out.push_str(kind);
        out.push('\n');
    };
    macro_rules! emit {
        ($name:expr, $value:expr) => {
            out.push_str(concat!("blasx_", $name));
            out.push(' ');
            out.push_str(&fmt_value($value));
            out.push('\n');
        };
        ($name:expr, $label:expr, $lv:expr, $value:expr) => {
            out.push_str(concat!("blasx_", $name));
            out.push_str(concat!("{", $label, "=\""));
            out.push_str(&$lv.to_string());
            out.push_str("\"} ");
            out.push_str(&fmt_value($value));
            out.push('\n');
        };
    }

    family("up", "Whether the resident runtime is booted.", "gauge");
    emit!("up", 1.0);
    family("uptime_seconds", "Seconds since the resident runtime booted.", "gauge");
    emit!("uptime_seconds", s.t_s);

    family("device_up", "1 while the device is alive, 0 once the fault plane killed it.", "gauge");
    for d in &s.devices {
        emit!("device_up", "dev", d.dev, if d.dead { 0.0 } else { 1.0 });
    }
    family("arena_bytes_in_use", "FastHeap bytes currently allocated on the device arena.", "gauge");
    for d in &s.devices {
        emit!("arena_bytes_in_use", "dev", d.dev, d.arena_in_use as f64);
    }
    family("arena_high_water_bytes", "FastHeap lifetime allocation high watermark.", "gauge");
    for d in &s.devices {
        emit!("arena_high_water_bytes", "dev", d.dev, d.arena_high_water as f64);
    }
    family("cache_resident_tiles", "Tiles resident in the device's ALRU cache.", "gauge");
    for d in &s.devices {
        emit!("cache_resident_tiles", "dev", d.dev, d.cache_resident as f64);
    }
    family(
        "cache_hit_rate",
        "ALRU hit rate over the last sampling window (0 when idle).",
        "gauge",
    );
    for d in &s.devices {
        emit!("cache_hit_rate", "dev", d.dev, d.hit_rate);
    }
    family("cache_hits_total", "Cumulative ALRU tile hits.", "counter");
    for d in &s.devices {
        emit!("cache_hits_total", "dev", d.dev, d.cache_hits as f64);
    }
    family("cache_misses_total", "Cumulative ALRU tile misses.", "counter");
    for d in &s.devices {
        emit!("cache_misses_total", "dev", d.dev, d.cache_misses as f64);
    }
    family("cache_evictions_total", "Cumulative ALRU tile evictions.", "counter");
    for d in &s.devices {
        emit!("cache_evictions_total", "dev", d.dev, d.cache_evictions as f64);
    }
    family(
        "worker_busy_fraction",
        "Fraction of the last sampling window the device worker spent inside rounds.",
        "gauge",
    );
    for d in &s.devices {
        emit!("worker_busy_fraction", "dev", d.dev, d.busy_fraction);
    }
    family("worker_rounds_total", "Cumulative scheduler rounds executed by the worker.", "counter");
    for d in &s.devices {
        emit!("worker_rounds_total", "dev", d.dev, d.rounds as f64);
    }
    family(
        "prefetch_hits_total",
        "Demand acquires served by a tile staged ahead of time by lookahead prefetch.",
        "counter",
    );
    for d in &s.devices {
        emit!("prefetch_hits_total", "dev", d.dev, d.prefetch_hits as f64);
    }
    family(
        "prefetch_wasted_total",
        "Prefetched tiles dropped unconsumed (TTL expiry, invalidation, pressure flush).",
        "counter",
    );
    for d in &s.devices {
        emit!("prefetch_wasted_total", "dev", d.dev, d.prefetch_wasted as f64);
    }
    family(
        "inflight_transfers",
        "Tile transfers (fills, preloads, write-backs) currently executing off the cache lock.",
        "gauge",
    );
    emit!("inflight_transfers", s.inflight_transfers as f64);

    family("queue_depth", "Jobs occupying admission-table slots.", "gauge");
    emit!("queue_depth", s.queue_depth as f64);
    family("jobs_runnable", "Admitted jobs with no unmet dependency edges.", "gauge");
    emit!("jobs_runnable", s.runnable as f64);
    family("jobs_blocked", "Admitted jobs waiting on dependency edges.", "gauge");
    emit!("jobs_blocked", s.blocked as f64);
    family("jobs_in_flight", "Jobs admitted and not yet retired.", "gauge");
    emit!("jobs_in_flight", s.in_flight as f64);
    family("jobs_admitted_total", "Jobs admitted since boot.", "counter");
    emit!("jobs_admitted_total", s.admitted as f64);
    family("jobs_retired_total", "Jobs retired since boot.", "counter");
    emit!("jobs_retired_total", s.retired as f64);
    family("jobs_failed_total", "Jobs retired with a failure since boot.", "counter");
    emit!("jobs_failed_total", s.failed as f64);
    family(
        "jobs_rejected_total",
        "Admissions refused with backpressure (capacity or tenant quota).",
        "counter",
    );
    emit!("jobs_rejected_total", s.rejected as f64);

    family("tenant_inflight", "Live jobs per submitting tenant.", "gauge");
    for &(tenant, n) in &s.per_tenant {
        emit!("tenant_inflight", "tenant", tenant, n as f64);
    }

    family("dispatch_shapes", "Shape buckets tracked by the adaptive dispatcher.", "gauge");
    emit!("dispatch_shapes", s.dispatch_shapes as f64);
    family(
        "dispatch_observations_total",
        "Timing observations folded into the dispatcher's online EWMAs.",
        "counter",
    );
    emit!("dispatch_observations_total", s.dispatch_observations as f64);
    out
}

/// The scrape body of a context whose runtime has not booted: the
/// liveness gauge alone, so a scraper sees a valid exposition instead
/// of an error.
pub fn render_unbooted() -> String {
    "# HELP blasx_up Whether the resident runtime is booted.\n# TYPE blasx_up gauge\nblasx_up 0\n"
        .to_string()
}

/// Prometheus floats: integral values print without a fraction (what
/// every exporter emits for counters); non-integral keep full
/// precision.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// One parsed exposition line: `(family, labels, value)`. Used by
/// `blasx top` and the tests; the CI checker re-implements this in
/// Python on the scrape side.
pub fn parse(text: &str) -> Vec<(String, Vec<(String, String)>, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name_part, value_part) = match line.rsplit_once(' ') {
            Some(p) => p,
            None => continue,
        };
        let Ok(value) = value_part.parse::<f64>() else { continue };
        let (name, labels) = match name_part.split_once('{') {
            None => (name_part.to_string(), Vec::new()),
            Some((n, rest)) => {
                let body = rest.trim_end_matches('}');
                let labels = body
                    .split(',')
                    .filter_map(|kv| {
                        let (k, v) = kv.split_once('=')?;
                        Some((k.trim().to_string(), v.trim().trim_matches('"').to_string()))
                    })
                    .collect();
                (n.to_string(), labels)
            }
        };
        out.push((name, labels, value));
    }
    out
}

/// The stdlib scrape endpoint (see module docs). Stop + join via
/// [`TelemetryServer::stop`] (also runs on drop).
pub struct TelemetryServer {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    addr: std::net::SocketAddr,
}

impl TelemetryServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`; port 0 picks a free one)
    /// and serve `/metrics` + `/healthz` for `ctx` until stopped.
    pub fn start(addr: &str, ctx: Context) -> std::io::Result<TelemetryServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("blasx-telemetry-http".into())
            .spawn(move || serve_loop(listener, ctx, stop2))
            .expect("spawn telemetry http thread");
        Ok(TelemetryServer { stop, handle: Some(handle), addr: local })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the server thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TelemetryServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_loop(listener: TcpListener, ctx: Context, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // One request per connection, handled inline: scrapers
                // are few and the body is tiny, so a thread pool would
                // be machinery without a workload.
                let _ = handle_conn(stream, &ctx);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

fn handle_conn(mut stream: std::net::TcpStream, ctx: &Context) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf)?;
    let req = String::from_utf8_lossy(&buf[..n]);
    let path = req.split_whitespace().nth(1).unwrap_or("/");
    let (status, ctype, body) = match path {
        p if p.starts_with("/metrics") => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            ctx.render_prometheus(),
        ),
        p if p.starts_with("/healthz") => {
            let (healthy, dead) = ctx.health();
            if healthy {
                ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string())
            } else {
                (
                    "503 Service Unavailable",
                    "text/plain; charset=utf-8",
                    format!(
                        "degraded: dead devices {}\n",
                        dead.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
                    ),
                )
            }
        }
        _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
    };
    let resp = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::telemetry::DevGauges;

    fn sample() -> TelemetrySample {
        let mut s = TelemetrySample { t_s: 12.5, ..Default::default() };
        s.devices.push(DevGauges {
            dev: 0,
            arena_in_use: 1024,
            arena_high_water: 4096,
            cache_resident: 7,
            cache_hits: 30,
            cache_misses: 10,
            hit_rate: 0.75,
            prefetch_hits: 9,
            prefetch_wasted: 2,
            busy_fraction: 0.5,
            rounds: 42,
            ..Default::default()
        });
        s.devices.push(DevGauges { dev: 1, dead: true, ..Default::default() });
        s.queue_depth = 3;
        s.runnable = 2;
        s.blocked = 1;
        s.in_flight = 3;
        s.admitted = 10;
        s.retired = 7;
        s.rejected = 1;
        s.per_tenant = vec![(1, 2), (2, 1)];
        s
    }

    #[test]
    fn render_emits_every_required_family() {
        let text = render(&sample());
        for family in [
            "blasx_up",
            "blasx_arena_bytes_in_use",
            "blasx_cache_hit_rate",
            "blasx_queue_depth",
            "blasx_tenant_inflight",
            "blasx_device_up",
            "blasx_jobs_rejected_total",
            "blasx_worker_busy_fraction",
            "blasx_prefetch_hits_total",
            "blasx_prefetch_wasted_total",
            "blasx_inflight_transfers",
        ] {
            assert!(text.contains(&format!("# TYPE {family} ")), "missing family {family}");
        }
        assert!(text.contains("blasx_device_up{dev=\"1\"} 0"), "dead device renders 0");
        assert!(text.contains("blasx_cache_hit_rate{dev=\"0\"} 0.75"));
        assert!(text.contains("blasx_tenant_inflight{tenant=\"2\"} 1"));
        assert!(text.contains("blasx_prefetch_hits_total{dev=\"0\"} 9"));
        assert!(text.contains("blasx_prefetch_wasted_total{dev=\"0\"} 2"));
    }

    #[test]
    fn parse_roundtrips_render() {
        let text = render(&sample());
        let parsed = parse(&text);
        let find = |name: &str, label: Option<(&str, &str)>| -> f64 {
            parsed
                .iter()
                .find(|(n, ls, _)| {
                    n == name
                        && label.map_or(true, |(k, v)| {
                            ls.iter().any(|(lk, lv)| lk == k && lv == v)
                        })
                })
                .unwrap_or_else(|| panic!("{name} not parsed"))
                .2
        };
        assert_eq!(find("blasx_up", None), 1.0);
        assert_eq!(find("blasx_queue_depth", None), 3.0);
        assert_eq!(find("blasx_arena_bytes_in_use", Some(("dev", "0"))), 1024.0);
        assert_eq!(find("blasx_device_up", Some(("dev", "1"))), 0.0);
        assert_eq!(find("blasx_cache_hit_rate", Some(("dev", "0"))), 0.75);
    }

    #[test]
    fn unbooted_body_is_valid_exposition() {
        let parsed = parse(&render_unbooted());
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].0, "blasx_up");
        assert_eq!(parsed[0].2, 0.0);
    }
}
