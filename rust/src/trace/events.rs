//! Execution-trace events: timed intervals per device classified by what
//! the hardware unit was doing — the raw material of the paper's Fig. 1
//! snapshots, Fig. 8 COMPT/COMM/OTHER dissection, and Table IV/V traffic
//! accounting.

/// What an interval on a device was spent on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvKind {
    /// Kernel execution (COMPT).
    Kernel,
    /// Host→device transfer.
    H2d,
    /// Device→host transfer (C write-backs).
    D2h,
    /// Peer-to-peer transfer (this device is the destination).
    P2p,
}

/// One timed interval.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub dev: usize,
    pub stream: usize,
    pub kind: EvKind,
    pub start: f64,
    pub end: f64,
    /// Bytes moved (transfers) or flops executed (kernels).
    pub amount: f64,
}

/// Append-only event log for one run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<Event>,
    /// Wall/virtual time the run finished.
    pub makespan: f64,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    pub fn push(&mut self, ev: Event) {
        debug_assert!(ev.end >= ev.start);
        self.events.push(ev);
    }

    pub fn record(
        &mut self,
        dev: usize,
        stream: usize,
        kind: EvKind,
        start: f64,
        end: f64,
        amount: f64,
    ) {
        self.push(Event { dev, stream, kind, start, end, amount });
    }

    /// Events of one device, in recorded order.
    pub fn of_device(&self, dev: usize) -> impl Iterator<Item = &Event> {
        self.events.iter().filter(move |e| e.dev == dev)
    }

    /// Bytes moved into/out of `dev` by kind.
    pub fn bytes(&self, dev: usize, kind: EvKind) -> f64 {
        debug_assert!(kind != EvKind::Kernel);
        self.of_device(dev).filter(|e| e.kind == kind).map(|e| e.amount).sum()
    }

    /// Flops executed on `dev`.
    pub fn flops(&self, dev: usize) -> f64 {
        self.of_device(dev).filter(|e| e.kind == EvKind::Kernel).map(|e| e.amount).sum()
    }

    /// Highest device index + 1.
    pub fn n_devices(&self) -> usize {
        self.events.iter().map(|e| e.dev + 1).max().unwrap_or(0)
    }
}

/// Total length of the union of `[start, end)` intervals.
pub fn union_len(intervals: &mut Vec<(f64, f64)>) -> f64 {
    if intervals.is_empty() {
        return 0.0;
    }
    intervals.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut total = 0.0;
    let (mut cs, mut ce) = intervals[0];
    for &(s, e) in intervals.iter().skip(1) {
        if s > ce {
            total += ce - cs;
            cs = s;
            ce = e;
        } else {
            ce = ce.max(e);
        }
    }
    total + (ce - cs)
}

/// Length of the part of interval-set `a` not covered by interval-set
/// `b` (both get sorted/merged). Used for "unoverlapped communication":
/// COMM = |transfers \ kernels|.
pub fn uncovered_len(a: &mut Vec<(f64, f64)>, b: &mut Vec<(f64, f64)>) -> f64 {
    let total_a = union_len(a); // sorts & merges a conceptually
    if b.is_empty() {
        return total_a;
    }
    // merge b
    b.sort_by(|x, y| x.0.total_cmp(&y.0));
    let mut merged_b: Vec<(f64, f64)> = Vec::with_capacity(b.len());
    for &(s, e) in b.iter() {
        match merged_b.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => merged_b.push((s, e)),
        }
    }
    // subtract: walk a (already sorted by union_len) against merged_b
    let mut covered = 0.0;
    let mut j = 0;
    // merge a again for a clean pass
    let mut merged_a: Vec<(f64, f64)> = Vec::with_capacity(a.len());
    for &(s, e) in a.iter() {
        match merged_a.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => merged_a.push((s, e)),
        }
    }
    for &(s, e) in &merged_a {
        while j < merged_b.len() && merged_b[j].1 <= s {
            j += 1;
        }
        let mut k = j;
        while k < merged_b.len() && merged_b[k].0 < e {
            let (bs, be) = merged_b[k];
            covered += (e.min(be) - s.max(bs)).max(0.0);
            k += 1;
        }
    }
    total_a - covered
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_merges_overlaps() {
        let mut v = vec![(0.0, 2.0), (1.0, 3.0), (5.0, 6.0)];
        assert_eq!(union_len(&mut v), 4.0);
        let mut single = vec![(1.0, 1.5)];
        assert_eq!(union_len(&mut single), 0.5);
        let mut empty: Vec<(f64, f64)> = vec![];
        assert_eq!(union_len(&mut empty), 0.0);
    }

    #[test]
    fn uncovered_subtracts() {
        // transfers [0,4), kernels [1,2)+[3,5): uncovered = [0,1)+[2,3) = 2
        let mut a = vec![(0.0, 4.0)];
        let mut b = vec![(1.0, 2.0), (3.0, 5.0)];
        assert_eq!(uncovered_len(&mut a, &mut b), 2.0);
        // fully covered
        let mut a2 = vec![(1.0, 2.0)];
        let mut b2 = vec![(0.0, 3.0)];
        assert_eq!(uncovered_len(&mut a2, &mut b2), 0.0);
        // no kernels: everything uncovered
        let mut a3 = vec![(0.0, 1.0), (2.0, 3.0)];
        let mut b3: Vec<(f64, f64)> = vec![];
        assert_eq!(uncovered_len(&mut a3, &mut b3), 2.0);
    }

    #[test]
    fn trace_accounting() {
        let mut t = Trace::new();
        t.record(0, 0, EvKind::Kernel, 0.0, 1.0, 100.0);
        t.record(0, 1, EvKind::H2d, 0.5, 0.8, 64.0);
        t.record(1, 0, EvKind::P2p, 0.0, 0.2, 32.0);
        t.makespan = 1.0;
        assert_eq!(t.flops(0), 100.0);
        assert_eq!(t.bytes(0, EvKind::H2d), 64.0);
        assert_eq!(t.bytes(1, EvKind::P2p), 32.0);
        assert_eq!(t.n_devices(), 2);
    }
}
