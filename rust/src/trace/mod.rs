//! Trace and metrics (system S16): everything the paper's evaluation
//! section measures — per-device COMPT/COMM/OTHER (Fig. 8), comm volume
//! split H↔D vs P2P (Table V), DMA throughput (Table IV), load-balance
//! gaps, and ASCII gantt snapshots (Fig. 1).

pub mod chrome;
pub mod events;
pub mod flight;
pub mod gantt;
pub mod metrics;
pub mod profile;
pub mod prometheus;
pub mod spans;
pub mod telemetry;

pub use chrome::chrome_trace;
pub use events::{EvKind, Event, Trace};
pub use flight::{FlightEvent, FlightRecorder, FLIGHT_RING};
pub use metrics::{tenant_id, Histogram, MetricsRegistry, RetiredJob};
pub use profile::{
    all_profiles, balance_gap, comm_volumes, device_profile, overlap_report, CommVolume,
    DeviceOverlap, DeviceProfile, OverlapReport,
};
pub use prometheus::TelemetryServer;
pub use spans::{JobRec, Recorder, Span, SpanKind};
pub use telemetry::{DevGauges, Telemetry, TelemetrySample, TELEMETRY_RING};
