//! Black-box flight recorder: the always-on incident trail.
//!
//! The span [`Recorder`](super::spans::Recorder) is opt-in and
//! unbounded — perfect for a profiling session, useless for explaining
//! why device 1 died at 03:12 on a fleet that was not being traced.
//! The [`FlightRecorder`] is the complement: **always on**, **bounded
//! memory** (fixed-capacity rings of fixed-size events, allocated once
//! at core construction), recording the last [`FLIGHT_RING`] scheduler
//! and per-device events — admissions, backpressure rejections,
//! retirements, faults, migrations, deadline reaps, worker panics.
//!
//! When something goes wrong (a `FaultAction` kill, a deadline reap, a
//! contained worker panic) the runtime calls
//! [`FlightRecorder::maybe_dump`], which — if `BLASX_FLIGHT_DIR` is set
//! or a directory was installed programmatically — writes an **incident
//! report**: a structured JSON document (schema `blasx-incident-v1`)
//! plus a Chrome trace-event file of the ring contents, so the minutes
//! before the event are replayable in Perfetto. PR 7's "bit-for-bit
//! recovery" claim stops being trust-the-test and becomes an artifact.
//!
//! ## Overhead contract
//!
//! Recording is lock-push-unlock into a preallocated ring slot: no
//! allocation ever happens after construction (pinned by
//! `rust/tests/telemetry.rs` with the counting allocator), and events
//! are recorded at *job* frequency (admit/retire/fault), not tile
//! frequency, so the clock read per event is noise. Dumps are bounded
//! per reason ([`DUMPS_PER_REASON`]) so a chaos schedule cannot fill a
//! disk.

use crate::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Events retained per ring (one ring per device + one scheduler ring).
pub const FLIGHT_RING: usize = 256;

/// Auto-dumps written per distinct reason before suppression kicks in
/// (a kill schedule with `x20` repeats must not write 20 reports).
pub const DUMPS_PER_REASON: u64 = 4;

/// One fixed-size flight event. `dev < 0` means "scheduler" (admission
/// plane) rather than a device worker.
#[derive(Clone, Copy, Debug)]
pub struct FlightEvent {
    /// Seconds since the recorder epoch (core construction).
    pub t_s: f64,
    /// `admit`, `reject`, `retire`, `fault`, `migrate`, `reap`,
    /// `panic`, `retry`, `degrade`.
    pub kind: &'static str,
    pub dev: i64,
    pub job: u64,
    pub tenant: u32,
    /// Kind-specific payload: weight (admit), failed flag (retire),
    /// moved tasks (migrate), attempt (retry), ...
    pub amount: f64,
}

/// Fixed-capacity overwrite ring. The backing `Vec` is allocated to
/// capacity up front; pushes past capacity overwrite the oldest slot.
struct Ring {
    buf: Vec<FlightEvent>,
    /// Next slot to overwrite once the ring is full.
    head: usize,
    /// Lifetime events pushed (≥ `buf.len()`).
    total: u64,
}

impl Ring {
    fn new() -> Ring {
        Ring { buf: Vec::with_capacity(FLIGHT_RING), head: 0, total: 0 }
    }

    fn push(&mut self, e: FlightEvent) {
        if self.buf.len() < FLIGHT_RING {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.head = (self.head + 1) % FLIGHT_RING;
        }
        self.total += 1;
    }

    /// Events oldest-first.
    fn ordered(&self) -> Vec<FlightEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }
}

/// The always-on black box (see module docs). One per `EngineCore`.
pub struct FlightRecorder {
    epoch: Instant,
    /// `rings[dev]` per device; `rings[n_devices]` is the scheduler
    /// ring (admission/backpressure/retire/reap events have no device).
    rings: Vec<Mutex<Ring>>,
    /// Fast gate for [`FlightRecorder::maybe_dump`]: set iff a dump
    /// directory is installed.
    armed: AtomicBool,
    dir: Mutex<Option<PathBuf>>,
    /// Incident sequence number (names the report files).
    seq: AtomicU64,
    /// Per-reason dump counts (bounded flood control). Reasons are a
    /// small closed set of static strings, so this map never grows past
    /// a handful of entries.
    per_reason: Mutex<std::collections::HashMap<&'static str, u64>>,
}

impl FlightRecorder {
    /// A recorder for `n_devices`, auto-dump armed iff
    /// `BLASX_FLIGHT_DIR` names a directory.
    pub fn new(n_devices: usize) -> FlightRecorder {
        let dir = std::env::var("BLASX_FLIGHT_DIR")
            .ok()
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .map(PathBuf::from);
        FlightRecorder {
            epoch: Instant::now(),
            rings: (0..n_devices.max(1) + 1).map(|_| Mutex::new(Ring::new())).collect(),
            armed: AtomicBool::new(dir.is_some()),
            dir: Mutex::new(dir),
            seq: AtomicU64::new(0),
            per_reason: Mutex::new(std::collections::HashMap::new()),
        }
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Install (or clear) the auto-dump directory programmatically —
    /// the test-friendly override of `BLASX_FLIGHT_DIR`.
    pub fn set_dump_dir(&self, dir: Option<PathBuf>) {
        self.armed.store(dir.is_some(), Ordering::Relaxed);
        *self.dir.lock().unwrap_or_else(|e| e.into_inner()) = dir;
    }

    /// Is auto-dump armed (a directory installed)?
    pub fn is_armed(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    /// Record one event. `dev = None` targets the scheduler ring.
    /// Never allocates: the ring slot is preallocated.
    pub fn record(&self, dev: Option<usize>, kind: &'static str, job: u64, tenant: u32, amount: f64) {
        let n = self.rings.len() - 1;
        let ring = dev.map_or(n, |d| d.min(n - (n > 0) as usize).min(n));
        let e = FlightEvent {
            t_s: self.now(),
            kind,
            dev: dev.map_or(-1, |d| d as i64),
            job,
            tenant,
            amount,
        };
        self.rings[ring].lock().unwrap_or_else(|p| p.into_inner()).push(e);
    }

    /// Every retained event, oldest-first per ring, then merged by
    /// timestamp.
    pub fn snapshot(&self) -> Vec<FlightEvent> {
        let mut out = Vec::new();
        for ring in &self.rings {
            out.extend(ring.lock().unwrap_or_else(|p| p.into_inner()).ordered());
        }
        out.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
        out
    }

    /// Lifetime events recorded (across all rings; not capped by ring
    /// capacity).
    pub fn total_events(&self) -> u64 {
        self.rings.iter().map(|r| r.lock().unwrap_or_else(|p| p.into_inner()).total).sum()
    }

    /// Events currently retained (bounded by
    /// `(n_devices + 1) × FLIGHT_RING` forever).
    pub fn retained(&self) -> usize {
        self.rings.iter().map(|r| r.lock().unwrap_or_else(|p| p.into_inner()).buf.len()).sum()
    }

    /// Auto-dump on an incident trigger: no-op unless a dump directory
    /// is armed and the per-reason budget remains. Returns the report
    /// path when a dump was written. Dump failures are reported through
    /// the logger, never panicked — the flight recorder must not make
    /// an incident worse.
    pub fn maybe_dump(&self, reason: &'static str, dead_devices: &[usize]) -> Option<PathBuf> {
        if !self.is_armed() {
            return None;
        }
        {
            let mut counts = self.per_reason.lock().unwrap_or_else(|p| p.into_inner());
            let c = counts.entry(reason).or_insert(0);
            if *c >= DUMPS_PER_REASON {
                return None;
            }
            *c += 1;
        }
        let dir = self.dir.lock().unwrap_or_else(|p| p.into_inner()).clone()?;
        match self.dump(&dir, reason, dead_devices) {
            Ok(path) => Some(path),
            Err(e) => {
                crate::util::logger::warn("flight", &format!("incident dump failed: {e}"));
                None
            }
        }
    }

    /// Write an incident report now: `incident_<seq>_<reason>.json`
    /// (schema `blasx-incident-v1`) plus the matching
    /// `incident_<seq>_<reason>.trace.json` Chrome trace of the ring
    /// contents. Returns the JSON report path.
    pub fn dump(&self, dir: &Path, reason: &str, dead_devices: &[usize]) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        let safe_reason: String =
            reason.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '-' }).collect();
        let events = self.snapshot();
        let report = incident_report(seq, reason, dead_devices, &events, self.now());
        let trace = flight_chrome_trace(&events, self.rings.len() - 1);
        let report_path = dir.join(format!("incident_{seq:04}_{safe_reason}.json"));
        let trace_path = dir.join(format!("incident_{seq:04}_{safe_reason}.trace.json"));
        std::fs::write(&report_path, report.to_string_pretty())?;
        std::fs::write(&trace_path, trace.to_string_compact())?;
        Ok(report_path)
    }
}

/// Build the structured incident report (schema `blasx-incident-v1`).
fn incident_report(
    seq: u64,
    reason: &str,
    dead_devices: &[usize],
    events: &[FlightEvent],
    t_s: f64,
) -> Json {
    let mut doc = Json::obj();
    doc.set("schema", "blasx-incident-v1".into())
        .set("seq", seq.into())
        .set("reason", reason.into())
        .set("t_s", Json::Num(t_s))
        .set("dead_devices", dead_devices.to_vec().into());
    let mut evs = Vec::with_capacity(events.len());
    let mut by_kind: std::collections::BTreeMap<&'static str, u64> = Default::default();
    for e in events {
        *by_kind.entry(e.kind).or_insert(0) += 1;
        let mut o = Json::obj();
        o.set("t_s", Json::Num(e.t_s))
            .set("kind", e.kind.into())
            .set("dev", e.dev.into())
            .set("job", e.job.into())
            .set("tenant", (e.tenant as u64).into())
            .set("amount", Json::Num(e.amount));
        evs.push(o);
    }
    doc.set("events", Json::Arr(evs));
    let mut counters = Json::obj();
    for (k, v) in by_kind {
        counters.set(k, v.into());
    }
    doc.set("event_counts", counters);
    doc
}

/// Render the ring contents as a Chrome trace-event document: instant
/// events ("i" phase) on one track per device plus a `scheduler` track,
/// loadable in Perfetto alongside the full PR 6 trace when one exists.
fn flight_chrome_trace(events: &[FlightEvent], n_devices: usize) -> Json {
    let mut all: Vec<Json> = Vec::with_capacity(events.len() + n_devices + 2);
    let mut meta = |tid: usize, name: &str| {
        let mut ev = Json::obj();
        ev.set("ph", "M".into())
            .set("pid", 0usize.into())
            .set("tid", tid.into())
            .set("name", "thread_name".into());
        let mut args = Json::obj();
        args.set("name", name.into());
        ev.set("args", args);
        ev
    };
    {
        let mut p = Json::obj();
        p.set("ph", "M".into()).set("pid", 0usize.into()).set("name", "process_name".into());
        let mut args = Json::obj();
        args.set("name", "flight".into());
        p.set("args", args);
        all.push(p);
    }
    for d in 0..n_devices {
        all.push(meta(d, &format!("device {d}")));
    }
    all.push(meta(n_devices, "scheduler"));
    for e in events {
        let tid = if e.dev < 0 { n_devices } else { e.dev as usize };
        let mut ev = Json::obj();
        ev.set("ph", "i".into())
            .set("s", "t".into())
            .set("pid", 0usize.into())
            .set("tid", tid.into())
            .set("name", e.kind.into())
            .set("ts", Json::Num((e.t_s * 1e6).max(0.0)));
        let mut args = Json::obj();
        args.set("job", e.job.into())
            .set("tenant", (e.tenant as u64).into())
            .set("amount", Json::Num(e.amount));
        ev.set("args", args);
        all.push(ev);
    }
    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(all)).set("displayTimeUnit", "ms".into());
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn ring_overwrites_at_capacity() {
        let fr = FlightRecorder::new(1);
        for i in 0..(FLIGHT_RING * 3) as u64 {
            fr.record(Some(0), "admit", i, 1, 0.0);
        }
        let events = fr.snapshot();
        assert_eq!(events.len(), FLIGHT_RING, "ring must stay bounded");
        // The retained window is the most recent FLIGHT_RING events.
        assert_eq!(events[0].job, (FLIGHT_RING * 2) as u64);
        assert_eq!(events.last().unwrap().job, (FLIGHT_RING * 3 - 1) as u64);
        assert_eq!(fr.total_events(), (FLIGHT_RING * 3) as u64);
    }

    #[test]
    fn scheduler_events_take_their_own_ring() {
        let fr = FlightRecorder::new(2);
        fr.record(None, "admit", 1, 7, 2.0);
        fr.record(Some(1), "fault", 0, 0, 1.0);
        let events = fr.snapshot();
        assert_eq!(events.len(), 2);
        assert!(events.iter().any(|e| e.kind == "admit" && e.dev == -1 && e.tenant == 7));
        assert!(events.iter().any(|e| e.kind == "fault" && e.dev == 1));
    }

    #[test]
    fn dump_writes_parseable_report_and_trace() {
        let dir = std::env::temp_dir().join(format!("blasx_flight_{}", std::process::id()));
        let fr = FlightRecorder::new(2);
        fr.record(None, "admit", 1, 1, 100.0);
        fr.record(Some(1), "fault", 0, 0, 1.0);
        let path = fr.dump(&dir, "device-kill", &[1]).expect("dump");
        let report = json::parse(&std::fs::read_to_string(&path).unwrap()).expect("report parses");
        assert_eq!(report.get("schema").and_then(Json::as_str), Some("blasx-incident-v1"));
        assert_eq!(report.get("reason").and_then(Json::as_str), Some("device-kill"));
        let dead = report.get("dead_devices").and_then(Json::as_arr).unwrap();
        assert_eq!(dead[0].as_usize(), Some(1));
        assert_eq!(report.get("events").and_then(Json::as_arr).unwrap().len(), 2);
        let trace_path = path.with_extension("").with_extension("");
        let trace_file = dir.join(format!(
            "{}.trace.json",
            trace_path.file_name().unwrap().to_str().unwrap()
        ));
        let trace =
            json::parse(&std::fs::read_to_string(&trace_file).unwrap()).expect("trace parses");
        assert!(trace.get("traceEvents").and_then(Json::as_arr).unwrap().len() >= 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn maybe_dump_respects_arming_and_reason_budget() {
        let fr = FlightRecorder::new(1);
        fr.set_dump_dir(None);
        assert!(fr.maybe_dump("device-kill", &[0]).is_none(), "disarmed = no dump");
        let dir = std::env::temp_dir().join(format!("blasx_flightb_{}", std::process::id()));
        fr.set_dump_dir(Some(dir.clone()));
        assert!(fr.is_armed());
        fr.record(Some(0), "fault", 0, 0, 0.0);
        let mut written = 0;
        for _ in 0..(DUMPS_PER_REASON + 3) {
            if fr.maybe_dump("device-kill", &[0]).is_some() {
                written += 1;
            }
        }
        assert_eq!(written, DUMPS_PER_REASON, "per-reason flood control");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
