//! Chrome trace-event JSON export.
//!
//! Converts the [`Recorder`](super::spans::Recorder)'s wall-clock spans
//! and job lifecycles into the Trace Event Format consumed by
//! `chrome://tracing` and [Perfetto](https://ui.perfetto.dev): open the
//! file emitted by `blasx run --trace-out trace.json` (or
//! `blasx serve ... --trace-out`) and the scheduler's interleaving —
//! kernels overlapping transfers, steal probes, condvar parks, queued
//! vs running jobs — becomes a zoomable timeline.
//!
//! Track layout:
//! - `pid 0` ("devices"): one `tid` per device worker, carrying the
//!   per-device phase spans (`kernel`, `h2d`, `d2h`, `p2p`, `pack`,
//!   `round`, `steal`, `park`).
//! - `pid 1` ("jobs"): one `tid` per admitted job, carrying two spans —
//!   `queued` (admission → first scheduler round) and `running`
//!   (first round → retire) — so queue-wait is visually separable from
//!   service time.
//!
//! Timestamps are microseconds since the recorder epoch ("X" complete
//! events with `ts`/`dur`), with "M" metadata events naming every
//! process and thread. Events are emitted sorted by `ts` so validators
//! and streaming viewers see a monotone file.

use super::spans::{JobRec, Span, SpanKind};
use crate::util::json::Json;

const PID_DEVICES: usize = 0;
const PID_JOBS: usize = 1;

fn span_name(kind: SpanKind) -> &'static str {
    match kind {
        SpanKind::Kernel => "kernel",
        SpanKind::H2d => "h2d",
        SpanKind::D2h => "d2h",
        SpanKind::P2p => "p2p",
        SpanKind::Pack => "pack",
        SpanKind::Round => "round",
        SpanKind::Steal => "steal",
        SpanKind::Park => "park",
        SpanKind::Fault => "fault",
        SpanKind::Retry => "retry",
        SpanKind::Migrate => "migrate",
        SpanKind::Prefetch => "prefetch",
        SpanKind::HostFallback => "host-fallback",
    }
}

fn micros(seconds: f64) -> f64 {
    (seconds * 1e6).max(0.0)
}

fn meta_event(pid: usize, tid: Option<usize>, name: &str, value: &str) -> Json {
    let mut ev = Json::obj();
    ev.set("ph", Json::Str("M".into()))
        .set("pid", Json::Num(pid as f64))
        .set("name", Json::Str(name.into()));
    if let Some(tid) = tid {
        ev.set("tid", Json::Num(tid as f64));
    }
    let mut args = Json::obj();
    args.set("name", Json::Str(value.into()));
    ev.set("args", args);
    ev
}

fn complete_event(
    pid: usize,
    tid: usize,
    name: &str,
    start_s: f64,
    end_s: f64,
    args: Json,
) -> Json {
    let ts = micros(start_s);
    let dur = (micros(end_s) - ts).max(0.0);
    let mut ev = Json::obj();
    ev.set("ph", Json::Str("X".into()))
        .set("pid", Json::Num(pid as f64))
        .set("tid", Json::Num(tid as f64))
        .set("name", Json::Str(name.into()))
        .set("ts", Json::Num(ts))
        .set("dur", Json::Num(dur))
        .set("args", args);
    ev
}

/// Build a Chrome trace-event document from recorder snapshots.
///
/// The result has the standard top-level shape
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}`; serialize it with
/// [`Json::to_string_compact`] and load the file in Perfetto.
pub fn chrome_trace(spans: &[Span], jobs: &[JobRec]) -> Json {
    let mut events: Vec<(f64, Json)> = Vec::new();

    // Metadata first: name both processes and every track that will
    // carry events.
    let mut meta: Vec<Json> = vec![
        meta_event(PID_DEVICES, None, "process_name", "devices"),
        meta_event(PID_JOBS, None, "process_name", "jobs"),
    ];
    let mut devs: Vec<usize> = spans.iter().map(|s| s.dev).collect();
    devs.sort_unstable();
    devs.dedup();
    for dev in devs {
        meta.push(meta_event(
            PID_DEVICES,
            Some(dev),
            "thread_name",
            &format!("device {dev}"),
        ));
    }
    for j in jobs {
        meta.push(meta_event(
            PID_JOBS,
            Some(j.job as usize),
            "thread_name",
            &format!("job {} [{} t{}]", j.job, j.routine, j.tenant),
        ));
    }

    for s in spans {
        let mut args = Json::obj();
        args.set("amount", Json::Num(s.amount));
        if s.job != 0 {
            args.set("job", Json::Num(s.job as f64));
        }
        events.push((
            s.start,
            complete_event(PID_DEVICES, s.dev, span_name(s.kind), s.start, s.end, args),
        ));
    }

    for j in jobs {
        let tid = j.job as usize;
        let mut qargs = Json::obj();
        qargs
            .set("tenant", Json::Num(j.tenant as f64))
            .set("routine", Json::Str(j.routine.into()));
        events.push((
            j.admit,
            complete_event(PID_JOBS, tid, "queued", j.admit, j.first_round, qargs),
        ));
        let mut rargs = Json::obj();
        rargs
            .set("tenant", Json::Num(j.tenant as f64))
            .set("routine", Json::Str(j.routine.into()))
            .set("failed", Json::Bool(j.failed));
        events.push((
            j.first_round,
            complete_event(PID_JOBS, tid, "running", j.first_round, j.retire, rargs),
        ));
    }

    // Monotone ts within the X events (metadata leads the array).
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut all = meta;
    all.extend(events.into_iter().map(|(_, ev)| ev));

    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(all))
        .set("displayTimeUnit", Json::Str("ms".into()));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample_spans() -> Vec<Span> {
        vec![
            Span { dev: 0, kind: SpanKind::Kernel, start: 0.002, end: 0.004, amount: 1e6, job: 3 },
            Span { dev: 1, kind: SpanKind::H2d, start: 0.001, end: 0.003, amount: 4096.0, job: 3 },
            Span { dev: 0, kind: SpanKind::Park, start: 0.004, end: 0.005, amount: 0.0, job: 0 },
        ]
    }

    fn sample_jobs() -> Vec<JobRec> {
        vec![JobRec {
            job: 3,
            tenant: 1,
            routine: "gemm",
            admit: 0.0005,
            first_round: 0.001,
            retire: 0.005,
            failed: false,
        }]
    }

    #[test]
    fn export_roundtrips_and_ts_is_monotone() {
        let doc = chrome_trace(&sample_spans(), &sample_jobs());
        let text = doc.to_string_compact();
        let parsed = json::parse(&text).expect("chrome trace must be valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert!(!events.is_empty());
        let mut last_ts = f64::NEG_INFINITY;
        let mut saw_x = 0;
        for ev in events {
            let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap();
            assert!(ev.get("pid").is_some());
            match ph {
                "M" => assert!(last_ts == f64::NEG_INFINITY, "metadata must lead"),
                "X" => {
                    saw_x += 1;
                    let ts = ev.get("ts").and_then(|t| t.as_f64()).unwrap();
                    let dur = ev.get("dur").and_then(|d| d.as_f64()).unwrap();
                    assert!(ts >= last_ts, "X events must be ts-sorted");
                    assert!(ts >= 0.0 && dur >= 0.0);
                    assert!(ev.get("tid").is_some());
                    last_ts = ts;
                }
                other => panic!("unexpected phase {other:?}"),
            }
        }
        assert_eq!(saw_x, 5, "3 device spans + queued + running");
    }

    #[test]
    fn device_and_job_tracks_are_separate_pids() {
        let doc = chrome_trace(&sample_spans(), &sample_jobs());
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        let pid_of = |name: &str| -> f64 {
            events
                .iter()
                .find(|ev| ev.get("name").and_then(|n| n.as_str()) == Some(name))
                .and_then(|ev| ev.get("pid"))
                .and_then(|p| p.as_f64())
                .unwrap()
        };
        assert_eq!(pid_of("kernel"), PID_DEVICES as f64);
        assert_eq!(pid_of("queued"), PID_JOBS as f64);
        assert_eq!(pid_of("running"), PID_JOBS as f64);
        // The queued span ends where the running span begins.
        let queued = events
            .iter()
            .find(|ev| ev.get("name").and_then(|n| n.as_str()) == Some("queued"))
            .unwrap();
        let running = events
            .iter()
            .find(|ev| ev.get("name").and_then(|n| n.as_str()) == Some("running"))
            .unwrap();
        let q_end = queued.get("ts").unwrap().as_f64().unwrap()
            + queued.get("dur").unwrap().as_f64().unwrap();
        let r_ts = running.get("ts").unwrap().as_f64().unwrap();
        assert!((q_end - r_ts).abs() < 1e-6);
    }

    #[test]
    fn empty_recorder_exports_valid_shell() {
        let doc = chrome_trace(&[], &[]);
        let parsed = json::parse(&doc.to_string_compact()).unwrap();
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // Just the two process_name metadata records.
        assert_eq!(events.len(), 2);
    }
}
