//! Fault-injection plane (system S17): deterministic chaos for the
//! resident runtime.
//!
//! Production multi-GPU serving cannot assume devices never fail or
//! arenas never fill; this module makes those events *schedulable* so
//! the recovery paths (task migration, surgical cache invalidation,
//! OOM degradation) are exercised by ordinary tests instead of waiting
//! for hardware to oblige. Two halves:
//!
//! - [`plan`]: the declarative schedule ([`FaultPlan`]), parsed from
//!   `BLASX_FAULTS` / `blasx_init` / `RunConfig::fault_plan`.
//! - [`Injector`]: the runtime side the engine consults at each
//!   operation site. **Zero cost when no plan is installed** — every
//!   probe is one relaxed atomic load, the same discipline as the
//!   span recorder.
//!
//! The injector only *reports* faults; the engine owns the reactions
//! (retry, migrate, degrade). That keeps every injection site a
//! one-line probe and the recovery logic testable against real fault
//! sources too (a genuine kernel error takes the same path as an
//! injected one).

pub mod plan;

pub use plan::{FaultKind, FaultPlan, FaultSpec, OpKind, Trigger};

use plan::prob_coin;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// What the engine should do about the operation it just probed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Proceed normally.
    None,
    /// This operation fails (transient) — retry it.
    FailOp,
    /// The device is lost as of this operation — migrate and degrade.
    Kill,
    /// The worker wedges here (bounded stall), then continues.
    Wedge,
}

/// Per-device op counters (one per [`OpKind`] stream).
struct DevCounters {
    ops: [AtomicU64; 5],
}

impl DevCounters {
    fn new() -> DevCounters {
        DevCounters { ops: Default::default() }
    }
}

/// The runtime half of the injection plane. One per `EngineCore`;
/// shared by all device workers.
pub struct Injector {
    /// Gate for the zero-cost-when-off contract: checked with one
    /// relaxed load before anything else.
    armed: AtomicBool,
    counters: Vec<DevCounters>,
    /// Installed plan (compiled form). Locked only on the armed path.
    plan: Mutex<FaultPlan>,
}

impl Injector {
    /// A disarmed injector for `n_devices` devices.
    pub fn new(n_devices: usize) -> Injector {
        Injector {
            armed: AtomicBool::new(false),
            counters: (0..n_devices).map(|_| DevCounters::new()).collect(),
            plan: Mutex::new(FaultPlan::default()),
        }
    }

    /// Install (or replace) the active plan. An empty plan disarms.
    /// Op counters restart from zero so a plan means the same thing
    /// regardless of when it is installed.
    pub fn install(&self, plan: FaultPlan) {
        for c in &self.counters {
            for op in &c.ops {
                op.store(0, Ordering::Relaxed);
            }
        }
        let armed = !plan.specs.is_empty();
        *self.plan.lock().unwrap_or_else(|p| p.into_inner()) = plan;
        self.armed.store(armed, Ordering::Relaxed);
    }

    pub fn is_armed(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    /// Probe a kernel op on `dev`. Kernel is the anchoring stream for
    /// `kill`/`wedge`, so this is the only probe that can return more
    /// than fail/none.
    #[inline]
    pub fn tick_kernel(&self, dev: usize) -> FaultAction {
        if !self.armed.load(Ordering::Relaxed) {
            return FaultAction::None;
        }
        self.tick_slow(dev, OpKind::Kernel)
    }

    /// Probe a transfer/alloc op on `dev`: `true` = this op fails.
    #[inline]
    pub fn tick(&self, dev: usize, kind: OpKind) -> bool {
        if !self.armed.load(Ordering::Relaxed) {
            return false;
        }
        self.tick_slow(dev, kind) == FaultAction::FailOp
    }

    fn tick_slow(&self, dev: usize, kind: OpKind) -> FaultAction {
        let Some(counters) = self.counters.get(dev) else {
            return FaultAction::None;
        };
        let op = counters.ops[kind.index()].fetch_add(1, Ordering::Relaxed);
        let plan = self.plan.lock().unwrap_or_else(|p| p.into_inner());
        let mut action = FaultAction::None;
        for spec in plan.specs.iter().filter(|s| s.dev == dev && s.kind.anchor() == kind) {
            let fires = match spec.trigger {
                Trigger::At { op: at, count } => op >= at && op < at + count,
                Trigger::Prob(p) => prob_coin(plan.seed, dev, kind, op) < p,
            };
            if !fires {
                continue;
            }
            // Severity order: a kill outranks a wedge outranks a
            // transient failure on the same op.
            let a = match spec.kind {
                FaultKind::Kill => FaultAction::Kill,
                FaultKind::Wedge => FaultAction::Wedge,
                FaultKind::FailOp(_) => FaultAction::FailOp,
            };
            if severity(a) > severity(action) {
                action = a;
            }
        }
        action
    }
}

fn severity(a: FaultAction) -> u8 {
    match a {
        FaultAction::None => 0,
        FaultAction::FailOp => 1,
        FaultAction::Wedge => 2,
        FaultAction::Kill => 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(text: &str) -> FaultPlan {
        FaultPlan::parse(text).unwrap()
    }

    #[test]
    fn disarmed_injector_never_fires() {
        let inj = Injector::new(2);
        assert!(!inj.is_armed());
        for _ in 0..100 {
            assert_eq!(inj.tick_kernel(0), FaultAction::None);
            assert!(!inj.tick(1, OpKind::H2d));
        }
    }

    #[test]
    fn exact_op_triggers_fire_once_per_stream() {
        let inj = Injector::new(2);
        inj.install(plan("kernel@dev0:op2; h2d@dev1:op0x2"));
        assert!(inj.is_armed());
        let kernel_hits: Vec<bool> =
            (0..5).map(|_| inj.tick_kernel(0) == FaultAction::FailOp).collect();
        assert_eq!(kernel_hits, [false, false, true, false, false]);
        // a different device's stream is untouched
        assert_eq!(inj.tick_kernel(1), FaultAction::None);
        let h2d_hits: Vec<bool> = (0..4).map(|_| inj.tick(1, OpKind::H2d)).collect();
        assert_eq!(h2d_hits, [true, true, false, false], "x2 fails two consecutive ops");
    }

    #[test]
    fn kill_and_wedge_anchor_on_the_kernel_stream() {
        let inj = Injector::new(3);
        inj.install(plan("kill@dev2:op1; wedge@dev1:op0"));
        assert_eq!(inj.tick_kernel(1), FaultAction::Wedge);
        assert_eq!(inj.tick_kernel(1), FaultAction::None);
        assert_eq!(inj.tick_kernel(2), FaultAction::None);
        assert_eq!(inj.tick_kernel(2), FaultAction::Kill);
        // kill/wedge never fire on transfer probes
        assert!(!inj.tick(2, OpKind::H2d));
    }

    #[test]
    fn kill_outranks_transient_on_the_same_op() {
        let inj = Injector::new(1);
        inj.install(plan("kernel@dev0:op0; kill@dev0:op0"));
        assert_eq!(inj.tick_kernel(0), FaultAction::Kill);
    }

    #[test]
    fn probabilistic_triggers_are_seed_deterministic() {
        let run = |seed: u64| -> Vec<bool> {
            let inj = Injector::new(1);
            let mut p = plan("p2p@dev0:p0.3");
            p.seed = seed;
            inj.install(p);
            (0..64).map(|_| inj.tick(0, OpKind::P2p)).collect()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
        let fires = run(5).iter().filter(|&&b| b).count();
        assert!(fires > 5 && fires < 40, "p=0.3 over 64 ops fired {fires} times");
    }

    #[test]
    fn install_resets_counters_and_empty_plan_disarms() {
        let inj = Injector::new(1);
        inj.install(plan("kernel@dev0:op0"));
        assert_eq!(inj.tick_kernel(0), FaultAction::FailOp);
        inj.install(plan("kernel@dev0:op0"));
        assert_eq!(inj.tick_kernel(0), FaultAction::FailOp, "reinstall restarts op counting");
        inj.install(FaultPlan::default());
        assert!(!inj.is_armed());
        assert_eq!(inj.tick_kernel(0), FaultAction::None);
    }

    #[test]
    fn out_of_range_device_is_ignored() {
        let inj = Injector::new(1);
        inj.install(plan("kernel@dev7:op0"));
        assert_eq!(inj.tick_kernel(7), FaultAction::None);
    }
}
