//! Fault *plans*: the declarative half of the injection plane.
//!
//! A plan is a list of specs, each naming a device, an anchoring
//! operation stream on that device, and a trigger. The grammar (used
//! by `BLASX_FAULTS`, `blasx_init`'s `faults` field and
//! `RunConfig::fault_plan`) is one spec per `;`/`,`-separated token:
//!
//! ```text
//! kind@devD:opN[xC]     fire at the D-th device's N-th op (0-based),
//!                       C consecutive ops for transient kinds
//! kind@devD:pF          fire each op with probability F (seeded,
//!                       deterministic per (seed, dev, kind, op))
//! seed=S                seed for probabilistic triggers (default 0)
//! ```
//!
//! Kinds: `kill` (device lost), `wedge` (worker stalls once), `kernel`,
//! `h2d`, `d2h`, `p2p` (that single operation fails, the engine
//! retries), `oom` (the next arena allocation on the device fails).
//! `kill` and `wedge` anchor on the device's kernel-op stream; the
//! transient kinds anchor on their own stream. The `dev` prefix is
//! optional (`kill@1:op40` ≡ `kill@dev1:op40`).
//!
//! Example — the schedule used by the CI chaos job:
//!
//! ```text
//! BLASX_FAULTS="kill@dev1:op40; kernel@dev0:op3; h2d@dev0:op5x2"
//! ```

use crate::util::prng::splitmix64;

/// Operation streams that can be failed individually. Each device
/// counts each stream separately, starting at op 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// A tile-kernel execution (one k-step).
    Kernel,
    /// A host→device tile read.
    H2d,
    /// A device→host tile write-back.
    D2h,
    /// A device→device peer tile copy.
    P2p,
    /// A device-arena tile allocation.
    Alloc,
}

impl OpKind {
    pub const ALL: [OpKind; 5] =
        [OpKind::Kernel, OpKind::H2d, OpKind::D2h, OpKind::P2p, OpKind::Alloc];

    pub(crate) fn index(self) -> usize {
        match self {
            OpKind::Kernel => 0,
            OpKind::H2d => 1,
            OpKind::D2h => 2,
            OpKind::P2p => 3,
            OpKind::Alloc => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Kernel => "kernel",
            OpKind::H2d => "h2d",
            OpKind::D2h => "d2h",
            OpKind::P2p => "p2p",
            OpKind::Alloc => "oom",
        }
    }
}

/// What a spec does when its trigger fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The anchored operation fails once; the engine retries it.
    FailOp(OpKind),
    /// The device is lost: its tasks migrate to survivors, its cache
    /// entries are invalidated surgically, and it never runs again.
    Kill,
    /// The worker stalls (a bounded sleep) once — a wedged device that
    /// recovers; survivors steal its queued work meanwhile.
    Wedge,
}

impl FaultKind {
    /// The op stream whose counter this spec is matched against.
    pub(crate) fn anchor(self) -> OpKind {
        match self {
            FaultKind::FailOp(op) => op,
            // kill/wedge fire at a point in the device's kernel stream
            FaultKind::Kill | FaultKind::Wedge => OpKind::Kernel,
        }
    }
}

/// When a spec fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trigger {
    /// Ops `[op, op + count)` of the anchoring stream.
    At { op: u64, count: u64 },
    /// Every op independently with probability `p`, decided by a
    /// deterministic hash of (plan seed, dev, kind, op index).
    Prob(f64),
}

/// One injected fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    pub dev: usize,
    pub kind: FaultKind,
    pub trigger: Trigger,
}

/// A deterministic, seeded schedule of faults.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse the `BLASX_FAULTS` grammar. Returns `Err` with a message
    /// naming the first bad token.
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for token in text.split([';', ',']) {
            let token = token.trim();
            if token.is_empty() {
                continue;
            }
            if let Some(seed) = token.strip_prefix("seed=") {
                plan.seed =
                    seed.trim().parse().map_err(|_| format!("bad seed in `{token}`"))?;
                continue;
            }
            plan.specs.push(parse_spec(token)?);
        }
        Ok(plan)
    }

    /// Read and parse `BLASX_FAULTS`. An unset/empty variable is no
    /// plan; a malformed one is reported on stderr and ignored (chaos
    /// schedules must never take correct runs down with a typo).
    pub fn from_env() -> Option<FaultPlan> {
        let text = std::env::var("BLASX_FAULTS").ok()?;
        if text.trim().is_empty() {
            return None;
        }
        match FaultPlan::parse(&text) {
            Ok(plan) if plan.specs.is_empty() => None,
            Ok(plan) => Some(plan),
            Err(e) => {
                crate::util::logger::warn(
                    "fault",
                    &format!("ignoring malformed BLASX_FAULTS: {e}"),
                );
                None
            }
        }
    }

    /// The `serve --chaos` default: kill the highest device early in
    /// its kernel stream and sprinkle transient kernel/H2D failures on
    /// device 0 — a schedule every recovery path must survive.
    pub fn chaos_default(n_devices: usize, seed: u64) -> FaultPlan {
        let victim = n_devices.saturating_sub(1);
        let mut specs = vec![FaultSpec {
            dev: victim,
            kind: FaultKind::Kill,
            trigger: Trigger::At { op: 8, count: 1 },
        }];
        if n_devices > 1 {
            specs.push(FaultSpec {
                dev: 0,
                kind: FaultKind::FailOp(OpKind::Kernel),
                trigger: Trigger::At { op: 3, count: 1 },
            });
            specs.push(FaultSpec {
                dev: 0,
                kind: FaultKind::FailOp(OpKind::H2d),
                trigger: Trigger::At { op: 5, count: 2 },
            });
        }
        FaultPlan { seed, specs }
    }

    /// Does the plan hold a kill for `dev`? (The simulator uses this to
    /// model a degraded machine; the real engine fires it mid-run.)
    pub fn kills_device(&self, dev: usize) -> bool {
        self.specs.iter().any(|s| s.dev == dev && s.kind == FaultKind::Kill)
    }
}

fn parse_spec(token: &str) -> Result<FaultSpec, String> {
    let (kind_s, rest) =
        token.split_once('@').ok_or_else(|| format!("missing `@` in `{token}`"))?;
    let kind = match kind_s.trim() {
        "kill" => FaultKind::Kill,
        "wedge" => FaultKind::Wedge,
        "kernel" => FaultKind::FailOp(OpKind::Kernel),
        "h2d" => FaultKind::FailOp(OpKind::H2d),
        "d2h" => FaultKind::FailOp(OpKind::D2h),
        "p2p" => FaultKind::FailOp(OpKind::P2p),
        "oom" | "alloc" => FaultKind::FailOp(OpKind::Alloc),
        other => return Err(format!("unknown fault kind `{other}` in `{token}`")),
    };
    let (dev_s, trig_s) =
        rest.split_once(':').ok_or_else(|| format!("missing `:` in `{token}`"))?;
    let dev_s = dev_s.trim();
    let dev_s = dev_s.strip_prefix("dev").unwrap_or(dev_s);
    let dev: usize =
        dev_s.parse().map_err(|_| format!("bad device in `{token}`"))?;
    let trig_s = trig_s.trim();
    let trigger = if let Some(p) = trig_s.strip_prefix('p') {
        let p: f64 = p.parse().map_err(|_| format!("bad probability in `{token}`"))?;
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("probability out of [0,1] in `{token}`"));
        }
        Trigger::Prob(p)
    } else {
        let trig_s = trig_s.strip_prefix("op").unwrap_or(trig_s);
        let (op_s, count_s) = match trig_s.split_once('x') {
            Some((o, c)) => (o, Some(c)),
            None => (trig_s, None),
        };
        let op: u64 = op_s.parse().map_err(|_| format!("bad op index in `{token}`"))?;
        let count: u64 = match count_s {
            Some(c) => c.parse().map_err(|_| format!("bad repeat count in `{token}`"))?,
            None => 1,
        };
        if count == 0 {
            return Err(format!("zero repeat count in `{token}`"));
        }
        Trigger::At { op, count }
    };
    Ok(FaultSpec { dev, kind, trigger })
}

/// Deterministic per-op coin for probabilistic triggers: a hash of
/// (seed, dev, anchor kind, op index) mapped to [0, 1).
pub(crate) fn prob_coin(seed: u64, dev: usize, kind: OpKind, op: u64) -> f64 {
    let mut s = seed
        ^ (dev as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
        ^ (kind.index() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ op.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    let x = splitmix64(&mut s);
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse(
            "seed=7; kill@dev1:op40, wedge@2:3; kernel@dev0:op10x2; p2p@dev3:p0.25; oom@0:op1",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.specs.len(), 5);
        assert_eq!(
            p.specs[0],
            FaultSpec { dev: 1, kind: FaultKind::Kill, trigger: Trigger::At { op: 40, count: 1 } }
        );
        assert_eq!(
            p.specs[1],
            FaultSpec { dev: 2, kind: FaultKind::Wedge, trigger: Trigger::At { op: 3, count: 1 } }
        );
        assert_eq!(
            p.specs[2],
            FaultSpec {
                dev: 0,
                kind: FaultKind::FailOp(OpKind::Kernel),
                trigger: Trigger::At { op: 10, count: 2 },
            }
        );
        assert_eq!(
            p.specs[3],
            FaultSpec { dev: 3, kind: FaultKind::FailOp(OpKind::P2p), trigger: Trigger::Prob(0.25) }
        );
        assert_eq!(
            p.specs[4],
            FaultSpec {
                dev: 0,
                kind: FaultKind::FailOp(OpKind::Alloc),
                trigger: Trigger::At { op: 1, count: 1 },
            }
        );
        assert!(p.kills_device(1));
        assert!(!p.kills_device(0));
    }

    #[test]
    fn rejects_malformed_tokens() {
        for bad in [
            "kill",
            "kill@dev1",
            "explode@dev0:op1",
            "kernel@devX:op1",
            "kernel@dev0:opY",
            "kernel@dev0:p1.5",
            "kernel@dev0:op1x0",
            "seed=abc",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn empty_and_whitespace_plans_are_empty() {
        assert!(FaultPlan::parse("").unwrap().specs.is_empty());
        assert!(FaultPlan::parse(" ; , ").unwrap().specs.is_empty());
    }

    #[test]
    fn prob_coin_is_deterministic_and_uniform_ish() {
        let a = prob_coin(9, 1, OpKind::Kernel, 17);
        assert_eq!(a, prob_coin(9, 1, OpKind::Kernel, 17));
        assert_ne!(a, prob_coin(10, 1, OpKind::Kernel, 17));
        let n = 10_000;
        let mean: f64 =
            (0..n).map(|op| prob_coin(42, 0, OpKind::H2d, op)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn chaos_default_kills_the_last_device() {
        let p = FaultPlan::chaos_default(4, 1);
        assert!(p.kills_device(3));
        assert!(p.specs.len() >= 2, "chaos plan should also inject transient faults");
        let single = FaultPlan::chaos_default(1, 1);
        assert_eq!(single.specs.len(), 1, "one device: nothing survives transient noise");
    }
}
