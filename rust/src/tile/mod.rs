//! Tiled-matrix representation (paper §III).
//!
//! - [`layout::TileGrid`] — pure geometry: tile counts, edge-tile dims.
//! - [`matrix::HostMat`] — a column-major host buffer sliced into tiles;
//!   tiles are addressed by [`matrix::TileKey`] (the host address the
//!   paper's caches key on).

pub mod layout;
pub mod matrix;

pub use layout::TileGrid;
pub use matrix::{HostMat, MatId, TileKey};
