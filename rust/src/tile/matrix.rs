//! Host matrix wrapper and tile views (paper §III-A, §III-C).
//!
//! BLASX is out-of-core: input and output matrices always live in host
//! memory (a caller-provided column-major buffer, BLAS-style with a
//! leading dimension). The runtime never copies whole matrices — it
//! slices *tiles* out of the host buffer on demand.
//!
//! `HostMat` wraps a raw pointer + geometry and is shared across worker
//! threads. Safety rests on the paper's §IV-A task properties: tasks read
//! arbitrary input tiles concurrently but each task writes a distinct
//! output tile, so concurrent writes never alias.

use super::layout::TileGrid;
use crate::api::types::Scalar;
use std::sync::atomic::{AtomicU64, Ordering};

/// Identifies which operand of the current routine a tile belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MatId {
    A,
    B,
    C,
}

/// Globally-unique key for a tile: the paper keys its caches by the
/// tile's *host address*, which is exactly what `addr` is.
///
/// The operand role `mat` is **not** part of equality or hashing — it
/// is kept only for debug display and transfer accounting. A buffer
/// warmed through one role hits when later passed through another (a
/// weight matrix read as A in one call and as B in the next reuses its
/// cached tiles), and the real engine's consumer-side invariants
/// (diagonal identity padding) are re-asserted at acquire time rather
/// than baked into the key. The simulator's virtual key space keeps
/// per-role addresses disjoint (`KeyMap` reserves a span per operand),
/// so dropping `mat` changes nothing there.
///
/// The discriminants that *do* participate in equality make the key
/// safe beyond a single invocation:
///
/// - `ld` — the owning matrix's leading dimension. Two views of one
///   base pointer with different strides (a pointer-array batch whose
///   problems share a buffer) hold *different* bytes at the same tile
///   origin; without `ld` in the key they would alias each other's
///   cached tiles.
/// - `epoch` — the host-buffer invalidation generation stamped by the
///   persistent runtime (see `crate::runtime::service`). Bumping a
///   buffer's epoch makes every previously-cached tile of it
///   unreachable, which is how cross-call caching stays coherent when
///   an output is rewritten or the user mutates an input.
/// - `h`/`w` — the tile's *actual* (unpadded) extent. Two views of one
///   buffer with different row/col counts put different zero padding
///   in the same-origin cache block (an edge tile of the narrow view
///   is an interior tile of the wide one); without the extent in the
///   key, cross-role reuse would serve the wrong padding.
/// - `t` — the tile grid's nominal tile size: the *cache generation*
///   discriminant that lets tiles of different geometries coexist in
///   one cache. `h`/`w` alone cannot carry this: a 96-row matrix
///   viewed at `t=64` produces tile (1,0) with origin row 64 and
///   `h=32`, while the same buffer viewed at `t=32` produces tile
///   (2,0) with the *same* origin and the same `h=32` — identical
///   `(addr, ld, epoch, h, w)` — yet their cache blocks are stored
///   `t×t`-padded with layout stride `t`, so sharing one block across
///   the two views would serve bytes at the wrong stride. With `t` in
///   the key, a tile-size switch is simply a different generation of
///   keys: no barrier, no purge, and warm sets of other geometries
///   survive untouched.
#[derive(Clone, Copy, Debug)]
pub struct TileKey {
    /// Host address of the tile origin (the cache key, paper Alg. 2 "HA").
    pub addr: usize,
    /// Operand role — debug/accounting only, excluded from Eq/Hash.
    pub mat: MatId,
    pub ti: usize,
    pub tj: usize,
    /// Leading dimension of the owning matrix (stride discriminant).
    pub ld: usize,
    /// Host-buffer invalidation generation (0 = never invalidated /
    /// non-persistent run).
    pub epoch: u64,
    /// Actual tile extent (geometry discriminant; 0 for synthetic keys).
    pub h: usize,
    pub w: usize,
    /// Nominal tile size of the owning grid (per-geometry cache
    /// generation; 0 for synthetic keys). See the type docs for why
    /// `h`/`w` cannot substitute for it.
    pub t: usize,
}

impl PartialEq for TileKey {
    fn eq(&self, o: &TileKey) -> bool {
        // `mat` deliberately excluded — see the type docs.
        self.addr == o.addr
            && self.ti == o.ti
            && self.tj == o.tj
            && self.ld == o.ld
            && self.epoch == o.epoch
            && self.h == o.h
            && self.w == o.w
            && self.t == o.t
    }
}

impl Eq for TileKey {}

impl std::hash::Hash for TileKey {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        // Must mirror `eq`: `mat` stays out.
        self.addr.hash(state);
        self.ti.hash(state);
        self.tj.hash(state);
        self.ld.hash(state);
        self.epoch.hash(state);
        self.h.hash(state);
        self.w.hash(state);
        self.t.hash(state);
    }
}

impl TileKey {
    /// A key with no stride/epoch/extent discrimination — for unit
    /// tests and synthetic cache exercises where `addr` is already
    /// unique.
    pub fn synthetic(addr: usize, mat: MatId, ti: usize, tj: usize) -> TileKey {
        TileKey { addr, mat, ti, tj, ld: 0, epoch: 0, h: 0, w: 0, t: 0 }
    }
}

/// A column-major host matrix: base pointer, rows, cols, leading
/// dimension, and its tile grid.
pub struct HostMat<T> {
    ptr: *mut T,
    pub rows: usize,
    pub cols: usize,
    pub ld: usize,
    pub grid: TileGrid,
    pub id: MatId,
    /// Cross-call invalidation generation, folded into every
    /// [`TileKey`] this matrix produces. 0 until the persistent
    /// runtime stamps it at submit time (one-shot runs never do).
    epoch: AtomicU64,
}

// SAFETY: see module docs — tile tasks write disjoint regions; reads may
// race with nothing (inputs are never written during a call).
unsafe impl<T: Send> Send for HostMat<T> {}
unsafe impl<T: Sync> Sync for HostMat<T> {}

impl<T: Scalar> HostMat<T> {
    /// Wrap a caller buffer. `buf.len()` must cover `ld * cols` (the
    /// standard BLAS requirement) and `ld >= rows`.
    pub fn new(buf: &mut [T], rows: usize, cols: usize, ld: usize, t: usize, id: MatId) -> Self {
        assert!(ld >= rows.max(1), "leading dimension {ld} < rows {rows}");
        assert!(
            buf.len() >= ld * cols.saturating_sub(1) + rows || cols == 0,
            "buffer too small: len {} for ld {ld} x cols {cols}",
            buf.len()
        );
        HostMat {
            ptr: buf.as_mut_ptr(),
            rows,
            cols,
            ld,
            grid: TileGrid::new(rows, cols, t),
            id,
            epoch: AtomicU64::new(0),
        }
    }

    /// Wrap a read-only buffer. The runtime never writes through A/B
    /// operands; `MatId::C` must use [`HostMat::new`].
    pub fn new_ro(buf: &[T], rows: usize, cols: usize, ld: usize, t: usize, id: MatId) -> Self {
        assert!(id != MatId::C, "read-only wrap is for input operands");
        assert!(ld >= rows.max(1), "leading dimension {ld} < rows {rows}");
        assert!(
            buf.len() >= ld * cols.saturating_sub(1) + rows || cols == 0,
            "buffer too small"
        );
        HostMat {
            ptr: buf.as_ptr() as *mut T,
            rows,
            cols,
            ld,
            grid: TileGrid::new(rows, cols, t),
            id,
            epoch: AtomicU64::new(0),
        }
    }

    /// Wrap a raw column-major buffer (the scope-async and C-ABI
    /// doorways). Unlike [`HostMat::new`], no Rust reference to the
    /// buffer is created here — jobs whose operand ranges alias (the
    /// admission table orders them) must not conjure overlapping `&mut`
    /// slices even transiently.
    ///
    /// # Safety
    /// `ptr` must be valid for reads (and writes, if this operand is an
    /// output) of the `ld * (cols-1) + rows` element footprint for as
    /// long as any job referencing this wrap is in flight, and
    /// concurrent writers of overlapping ranges must be ordered by the
    /// caller (the admission table's conflict edges do this for jobs).
    pub(crate) unsafe fn from_raw(
        ptr: *mut T,
        rows: usize,
        cols: usize,
        ld: usize,
        t: usize,
        id: MatId,
    ) -> Self {
        debug_assert!(ld >= rows.max(1), "leading dimension {ld} < rows {rows}");
        HostMat {
            ptr,
            rows,
            cols,
            ld,
            grid: TileGrid::new(rows, cols, t),
            id,
            epoch: AtomicU64::new(0),
        }
    }

    /// Host address (usable as a cache key) of element `(r, c)`.
    #[inline]
    fn elem_addr(&self, r: usize, c: usize) -> usize {
        self.ptr as usize + (c * self.ld + r) * std::mem::size_of::<T>()
    }

    /// The cache key of tile `(ti, tj)`.
    #[inline]
    pub fn tile_key(&self, ti: usize, tj: usize) -> TileKey {
        let (h, w) = self.grid.tile_dims(ti, tj);
        TileKey {
            addr: self.elem_addr(self.grid.row_origin(ti), self.grid.col_origin(tj)),
            mat: self.id,
            ti,
            tj,
            ld: self.ld,
            epoch: self.epoch(),
            h,
            w,
            t: self.grid.t,
        }
    }

    /// The invalidation generation currently stamped on this wrap.
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Stamp the invalidation generation (persistent runtime, at submit
    /// time — before any tile key is derived by the workers).
    pub fn set_epoch(&self, e: u64) {
        self.epoch.store(e, Ordering::Relaxed);
    }

    /// Byte extent `[lo, hi)` of the wrapped column-major footprint —
    /// what the epoch registry overlaps against.
    pub fn byte_range(&self) -> (usize, usize) {
        let lo = self.ptr as usize;
        let elems = if self.cols == 0 { 0 } else { self.ld * (self.cols - 1) + self.rows };
        (lo, lo + elems * std::mem::size_of::<T>())
    }

    /// Copy tile `(ti, tj)` out of the host buffer into `dst`, laid out
    /// column-major with leading dimension `dst_ld` (≥ tile height). The
    /// remainder of `dst` (padding up to `dst_ld × dst_cols`) is left
    /// untouched — callers zero/identity-pad explicitly when needed.
    pub fn read_tile(&self, ti: usize, tj: usize, dst: &mut [T], dst_ld: usize) {
        let (h, w) = self.grid.tile_dims(ti, tj);
        debug_assert!(dst_ld >= h);
        debug_assert!(dst.len() >= dst_ld * w);
        let r0 = self.grid.row_origin(ti);
        let c0 = self.grid.col_origin(tj);
        for c in 0..w {
            // SAFETY: geometry checked above; source column segment lies
            // within the caller-provided buffer per the `new` contract.
            unsafe {
                let src = self.ptr.add((c0 + c) * self.ld + r0);
                std::ptr::copy_nonoverlapping(src, dst.as_mut_ptr().add(c * dst_ld), h);
            }
        }
    }

    /// Write `src` (column-major, leading dim `src_ld`) into tile
    /// `(ti, tj)` of the host buffer. This is the MESI-X M-state
    /// write-back path (paper Fig. 3).
    ///
    /// # Safety contract
    /// Only one in-flight task may write a given C tile (paper §IV-A);
    /// the taskizer guarantees distinct `(ti, tj)` per task.
    pub fn write_tile(&self, ti: usize, tj: usize, src: &[T], src_ld: usize) {
        let (h, w) = self.grid.tile_dims(ti, tj);
        debug_assert!(src_ld >= h);
        debug_assert!(src.len() >= src_ld * w);
        let r0 = self.grid.row_origin(ti);
        let c0 = self.grid.col_origin(tj);
        for c in 0..w {
            // SAFETY: as in `read_tile`; disjointness of writers is the
            // taskizer invariant documented above.
            unsafe {
                let dst = self.ptr.add((c0 + c) * self.ld + r0);
                std::ptr::copy_nonoverlapping(src.as_ptr().add(c * src_ld), dst, h);
            }
        }
    }

    /// Size in bytes of tile `(ti, tj)` as stored in a cache block
    /// (padded to the full `t × t` footprint so cache blocks are
    /// uniform, which is what lets the FastHeap recycle them freely).
    pub fn tile_padded_bytes(&self) -> usize {
        self.grid.t * self.grid.t * std::mem::size_of::<T>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(rows: usize, cols: usize, ld: usize) -> Vec<f64> {
        // element (r,c) = r + 100c, padding = -1
        let mut buf = vec![-1.0; ld * cols];
        for c in 0..cols {
            for r in 0..rows {
                buf[c * ld + r] = r as f64 + 100.0 * c as f64;
            }
        }
        buf
    }

    #[test]
    fn read_tile_interior_and_edge() {
        let mut buf = filled(5, 5, 7);
        let m = HostMat::new(&mut buf, 5, 5, 7, 2, MatId::A);
        // interior tile (1,1): rows 2..4, cols 2..4
        let mut t = vec![0.0; 4];
        m.read_tile(1, 1, &mut t, 2);
        assert_eq!(t, vec![2.0 + 200.0, 3.0 + 200.0, 2.0 + 300.0, 3.0 + 300.0]);
        // edge tile (2,2): single element (4,4)
        let mut e = vec![0.0; 1];
        m.read_tile(2, 2, &mut e, 1);
        assert_eq!(e, vec![4.0 + 400.0]);
    }

    #[test]
    fn write_tile_roundtrip() {
        let mut buf = filled(6, 6, 6);
        let m = HostMat::new(&mut buf, 6, 6, 6, 4, MatId::C);
        let src: Vec<f64> = (0..8).map(|x| 1000.0 + x as f64).collect();
        // tile (1,0): rows 4..6 (h=2), cols 0..4 (w=4), src_ld=2
        m.write_tile(1, 0, &src, 2);
        let mut back = vec![0.0; 8];
        m.read_tile(1, 0, &mut back, 2);
        assert_eq!(back, src);
        // Neighbouring tile untouched.
        let mut other = vec![0.0; 16];
        m.read_tile(0, 0, &mut other, 4);
        assert_eq!(other[0], 0.0);
        assert_eq!(other[5], 1.0 + 100.0);
    }

    #[test]
    fn tile_keys_unique_and_stable() {
        let mut buf = filled(8, 8, 8);
        let m = HostMat::new(&mut buf, 8, 8, 8, 4, MatId::A);
        let k00 = m.tile_key(0, 0);
        let k10 = m.tile_key(1, 0);
        let k01 = m.tile_key(0, 1);
        assert_ne!(k00.addr, k10.addr);
        assert_ne!(k00.addr, k01.addr);
        assert_eq!(k10.addr - k00.addr, 4 * 8); // 4 rows * 8 bytes
        assert_eq!(k01.addr - k00.addr, 4 * 8 * 8); // 4 cols * ld(8) * 8 bytes
        assert_eq!(m.tile_key(0, 0), k00);
    }

    #[test]
    fn ro_wrap_reads() {
        let buf = filled(4, 4, 4);
        let m = HostMat::<f64>::new_ro(&buf, 4, 4, 4, 2, MatId::B);
        let mut t = vec![0.0; 4];
        m.read_tile(0, 1, &mut t, 2);
        assert_eq!(t, vec![200.0, 201.0, 300.0, 301.0]);
    }

    #[test]
    #[should_panic(expected = "leading dimension")]
    fn rejects_bad_ld() {
        let mut buf = vec![0.0f64; 10];
        let _ = HostMat::new(&mut buf, 5, 2, 3, 2, MatId::A);
    }

    #[test]
    fn same_base_different_ld_keys_differ() {
        // Two views of one buffer with different strides hold different
        // bytes at the same tile origin — the keys must not alias
        // (pointer-array batch sharing a base pointer).
        let buf = vec![0.0f64; 41 * 64];
        let m40 = HostMat::<f64>::new_ro(&buf, 40, 60, 40, 32, MatId::A);
        let m41 = HostMat::<f64>::new_ro(&buf, 40, 60, 41, 32, MatId::A);
        // tile (1,0) origin address is ld-independent …
        assert_eq!(m40.tile_key(1, 0).addr, m41.tile_key(1, 0).addr);
        // … but the keys still differ via the stride discriminant
        assert_ne!(m40.tile_key(1, 0), m41.tile_key(1, 0));
    }

    #[test]
    fn epoch_bumps_change_keys() {
        let buf = vec![0.0f64; 8 * 8];
        let m = HostMat::<f64>::new_ro(&buf, 8, 8, 8, 4, MatId::B);
        let before = m.tile_key(0, 1);
        m.set_epoch(7);
        let after = m.tile_key(0, 1);
        assert_eq!(m.epoch(), 7);
        assert_ne!(before, after);
        assert_eq!((after.addr, after.ti, after.tj), (before.addr, before.ti, before.tj));
    }

    #[test]
    fn operand_role_is_not_part_of_key_equality() {
        // The same buffer wrapped as A and as B yields EQUAL keys for
        // the same tile: cross-role cache reuse (ROADMAP item closed by
        // the serve PR). `mat` survives for debug display only.
        let buf = vec![0.0f64; 64 * 64];
        let as_a = HostMat::<f64>::new_ro(&buf, 64, 64, 64, 32, MatId::A);
        let as_b = HostMat::<f64>::new_ro(&buf, 64, 64, 64, 32, MatId::B);
        let ka = as_a.tile_key(1, 0);
        let kb = as_b.tile_key(1, 0);
        assert_ne!(ka.mat, kb.mat);
        assert_eq!(ka, kb, "role must not block a warm hit");
        // …and they hash identically (HashMap lookup is the hit path).
        let mut set = std::collections::HashSet::new();
        set.insert(ka);
        assert!(set.contains(&kb));
    }

    #[test]
    fn different_view_extent_keys_differ() {
        // One buffer viewed with different row counts: tile (2,0) is a
        // full 32-row tile in the 100-row view but a 16-row edge tile
        // in the 80-row view — same origin address, different padding
        // contents. The extent discriminant keeps them apart.
        let buf = vec![0.0f64; 100 * 4];
        let wide = HostMat::<f64>::new_ro(&buf, 100, 4, 100, 32, MatId::A);
        let narrow = HostMat::<f64>::new_ro(&buf, 80, 4, 100, 32, MatId::B);
        let kw = wide.tile_key(2, 0);
        let kn = narrow.tile_key(2, 0);
        assert_eq!(kw.addr, kn.addr);
        assert_ne!(kw, kn, "edge-vs-interior views must not alias");
    }

    #[test]
    fn different_tile_size_generations_never_alias() {
        // The h/w-collision case from the TileKey docs: a 96-row
        // buffer at t=64 puts tile (1,0) at origin row 64 with h=32;
        // at t=32 tile (2,0) sits at the same origin with the same
        // h=32. Same addr/ld/epoch/h/w — only `t` keeps the two cache
        // generations apart (their blocks differ in stride and size).
        let buf = vec![0.0f64; 96 * 32];
        let g64 = HostMat::<f64>::new_ro(&buf, 96, 32, 96, 64, MatId::A);
        let g32 = HostMat::<f64>::new_ro(&buf, 96, 32, 96, 32, MatId::A);
        let k64 = g64.tile_key(1, 0);
        let k32 = g32.tile_key(2, 0);
        assert_eq!(k64.addr, k32.addr);
        assert_eq!((k64.ld, k64.epoch, k64.h), (k32.ld, k32.epoch, k32.h));
        assert_ne!(k64, k32, "tile-size generations must not share blocks");
        let mut set = std::collections::HashSet::new();
        set.insert(k64);
        assert!(!set.contains(&k32));
        // Within one generation the key is stable as ever.
        assert_eq!(g64.tile_key(1, 0), k64);
    }

    #[test]
    fn byte_range_covers_footprint() {
        let buf = vec![0.0f64; 10 * 5];
        let m = HostMat::<f64>::new_ro(&buf, 7, 5, 10, 4, MatId::A);
        let (lo, hi) = m.byte_range();
        assert_eq!(lo, buf.as_ptr() as usize);
        assert_eq!(hi - lo, (10 * 4 + 7) * 8);
    }

    #[test]
    fn padded_bytes() {
        let mut buf = filled(5, 5, 5);
        let m = HostMat::new(&mut buf, 5, 5, 5, 2, MatId::C);
        assert_eq!(m.tile_padded_bytes(), 2 * 2 * 8);
    }
}
