//! Tile grid geometry (paper §III-A).
//!
//! A matrix of `rows × cols` with tile size `t` is partitioned into
//! `ceil(rows/t) × ceil(cols/t)` tiles; interior tiles are `t × t` and
//! edge tiles are the remainders. Tiles are indexed `(ti, tj)` by tile
//! row and tile column.

/// Geometry of a tiled matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGrid {
    /// Matrix rows.
    pub rows: usize,
    /// Matrix columns.
    pub cols: usize,
    /// Tile size (square tiles; edge tiles truncated).
    pub t: usize,
}

impl TileGrid {
    pub fn new(rows: usize, cols: usize, t: usize) -> TileGrid {
        assert!(t > 0, "tile size must be positive");
        TileGrid { rows, cols, t }
    }

    /// Number of tile rows = ceil(rows / t).
    #[inline]
    pub fn tile_rows(&self) -> usize {
        self.rows.div_ceil(self.t)
    }

    /// Number of tile columns = ceil(cols / t).
    #[inline]
    pub fn tile_cols(&self) -> usize {
        self.cols.div_ceil(self.t)
    }

    /// Total number of tiles — the paper's degree of parallelism (Eq. 2)
    /// when applied to the output matrix.
    #[inline]
    pub fn num_tiles(&self) -> usize {
        self.tile_rows() * self.tile_cols()
    }

    /// Element-row origin of tile row `ti`.
    #[inline]
    pub fn row_origin(&self, ti: usize) -> usize {
        ti * self.t
    }

    /// Element-column origin of tile column `tj`.
    #[inline]
    pub fn col_origin(&self, tj: usize) -> usize {
        tj * self.t
    }

    /// Height of tile row `ti` (edge tiles may be short).
    #[inline]
    pub fn tile_height(&self, ti: usize) -> usize {
        debug_assert!(ti < self.tile_rows());
        (self.rows - ti * self.t).min(self.t)
    }

    /// Width of tile column `tj`.
    #[inline]
    pub fn tile_width(&self, tj: usize) -> usize {
        debug_assert!(tj < self.tile_cols());
        (self.cols - tj * self.t).min(self.t)
    }

    /// Dimensions `(h, w)` of tile `(ti, tj)`.
    #[inline]
    pub fn tile_dims(&self, ti: usize, tj: usize) -> (usize, usize) {
        (self.tile_height(ti), self.tile_width(tj))
    }

    /// Is `(ti, tj)` a full `t × t` interior tile?
    #[inline]
    pub fn is_full(&self, ti: usize, tj: usize) -> bool {
        self.tile_dims(ti, tj) == (self.t, self.t)
    }

    /// Number of full square tiles (paper §III-A's `⌊N/T⌋ × ⌊M/T⌋`).
    pub fn num_full_tiles(&self) -> usize {
        (self.rows / self.t) * (self.cols / self.t)
    }

    /// Iterate all tile indices in column-major order (matches the
    /// column-major element layout used throughout).
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let tr = self.tile_rows();
        let tc = self.tile_cols();
        (0..tc).flat_map(move |tj| (0..tr).map(move |ti| (ti, tj)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_division() {
        let g = TileGrid::new(8, 6, 2);
        assert_eq!(g.tile_rows(), 4);
        assert_eq!(g.tile_cols(), 3);
        assert_eq!(g.num_tiles(), 12);
        assert_eq!(g.num_full_tiles(), 12);
        assert!(g.is_full(3, 2));
        assert_eq!(g.tile_dims(0, 0), (2, 2));
    }

    #[test]
    fn ragged_edges() {
        let g = TileGrid::new(10, 7, 4);
        assert_eq!(g.tile_rows(), 3); // 4,4,2
        assert_eq!(g.tile_cols(), 2); // 4,3
        assert_eq!(g.tile_height(2), 2);
        assert_eq!(g.tile_width(1), 3);
        assert_eq!(g.tile_dims(2, 1), (2, 3));
        assert!(!g.is_full(2, 0));
        assert!(g.is_full(1, 0));
        assert_eq!(g.num_full_tiles(), 2); // floor(10/4)*floor(7/4) = 2*1
    }

    #[test]
    fn degenerate_small_matrix() {
        let g = TileGrid::new(3, 3, 1024);
        assert_eq!(g.num_tiles(), 1);
        assert_eq!(g.tile_dims(0, 0), (3, 3));
    }

    #[test]
    fn iter_covers_all_tiles_once() {
        let g = TileGrid::new(5, 5, 2);
        let all: Vec<_> = g.iter().collect();
        assert_eq!(all.len(), g.num_tiles());
        let mut dedup = all.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), all.len());
    }

    #[test]
    fn origins() {
        let g = TileGrid::new(100, 100, 32);
        assert_eq!(g.row_origin(2), 64);
        assert_eq!(g.col_origin(3), 96);
        assert_eq!(g.tile_height(3), 4); // 100 - 96
    }
}
