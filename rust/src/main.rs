//! `blasx` — the leader binary: CLI over the coordinator, simulator and
//! benchmark machinery. See `blasx --help` / `cli::usage()`.

fn main() {
    blasx::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(blasx::cli::dispatch(&argv));
}
