//! The asynchronous tile-transfer pipeline: lookahead prefetch must be
//! an *invisible* optimization — bit-for-bit identical results with it
//! on or off, under concurrent mixed-routine load, under injected
//! transfer/OOM faults, and under arena pressure where prefetched
//! blocks must expire rather than wedge the OOM ladder. The cache-level
//! tests pin the latch protocol itself: one racer fills, everyone else
//! waits off-lock, and a block mid-fill is never served over P2P.
//!
//! Run under both the default test harness and `RUST_TEST_THREADS=1`,
//! and in CI additionally with a `BLASX_FAULTS` schedule (the chaos
//! job) and with `BLASX_PREFETCH_DEPTH` exported over the concurrency
//! suites.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::{self, Context};
use blasx::cache::{AsyncAcquire, Source, TileCacheSet};
use blasx::fault::FaultPlan;
use blasx::mem::AllocStrategy;
use blasx::tile::{MatId, TileKey};
use blasx::util::prng::Prng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};

fn prefetch_ctx() -> Context {
    Context::new(2).with_arena(8 << 20).with_tile(32).with_prefetch(Some(4))
}

/// The healthy serial reference: same geometry, one-shot engine,
/// prefetch forced off (hermetic against `BLASX_PREFETCH_DEPTH` in the
/// environment — the chaos job exports it over this whole suite).
fn serial_ctx() -> Context {
    Context::new(2)
        .with_arena(8 << 20)
        .with_tile(32)
        .with_persistent(false)
        .with_prefetch(Some(0))
}

fn rand(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

fn upper_tri(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut a = rand(p, n * n);
    for x in a.iter_mut() {
        *x *= 0.5 / (n as f64).sqrt();
    }
    for i in 0..n {
        a[i * n + i] = 2.0;
    }
    a
}

/// One client's mixed-routine workload (dgemm → dsyrk → in-place
/// dtrsm on the dgemm output, twice). Returns the chain result and
/// the syrk output.
fn client_workload(ctx: &Context, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let (m, n, k) = (96, 64, 48);
    let mut p = Prng::new(seed);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let tri = upper_tri(&mut p, m);
    let sa = rand(&mut p, n * k);
    let mut c = vec![0.0; m * n];
    let mut sc = rand(&mut p, n * n);
    ctx.invalidate_host(&a);
    ctx.invalidate_host(&b);
    ctx.invalidate_host(&tri);
    ctx.invalidate_host(&sa);
    for _ in 0..2 {
        api::dgemm(ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
            .unwrap();
        api::syrk(ctx, Uplo::Lower, Trans::No, n, k, 0.7, &sa, n, 0.4, &mut sc, n).unwrap();
        api::trsm(ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0, &tri, m, &mut c, m)
            .unwrap();
    }
    (c, sc)
}

/// The headline invariant: 4 clients hammering one runtime with
/// lookahead prefetch enabled produce results bit-for-bit identical to
/// serial execution with prefetch off. Prefetch may move bytes early;
/// it must never change what a kernel computes or in which k-order.
#[test]
fn prefetch_on_concurrent_load_matches_serial_bit_for_bit() {
    let ctx = prefetch_ctx();
    let results: Vec<(u64, Vec<f64>, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4u64)
            .map(|seed| {
                let ctx = ctx.clone();
                scope.spawn(move || {
                    let (c, sc) = client_workload(&ctx, 600 + seed);
                    (seed, c, sc)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(ctx.runtime_calls(), 24);
    assert_eq!(ctx.jobs_in_flight(), 0);
    for (seed, c, sc) in results {
        let (want_c, want_sc) = client_workload(&serial_ctx(), 600 + seed);
        assert_eq!(c, want_c, "client {seed}: chain diverged with prefetch on");
        assert_eq!(sc, want_sc, "client {seed}: syrk diverged with prefetch on");
    }
}

/// Transfer and allocation faults landing on the prefetch path must be
/// absorbed by the same ladders as demand fills: bounded idempotent
/// redo for h2d/p2p, sync-and-retry (which flushes the prefetch
/// ledger) then host degradation for OOM. No wedge, no divergence.
#[test]
fn faults_on_prefetch_path_stay_bit_for_bit() {
    let plan =
        FaultPlan::parse("h2d@dev0:op2x3; p2p@dev1:op4x2; oom@dev0:op6; kernel@dev1:op8")
            .unwrap();
    let ctx = Context::new(2)
        .with_arena(8 << 20)
        .with_tile(32)
        .with_prefetch(Some(4))
        .with_fault_plan(Some(plan));
    let results: Vec<(u64, Vec<f64>, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4u64)
            .map(|seed| {
                let ctx = ctx.clone();
                scope.spawn(move || {
                    let (c, sc) = client_workload(&ctx, 650 + seed);
                    (seed, c, sc)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(ctx.jobs_in_flight(), 0, "fault recovery must not leak in-flight jobs");
    for (seed, c, sc) in results {
        let (want_c, want_sc) = client_workload(&serial_ctx(), 650 + seed);
        assert_eq!(c, want_c, "client {seed}: chain diverged under faulted prefetch");
        assert_eq!(sc, want_sc, "client {seed}: syrk diverged under faulted prefetch");
    }
}

/// A cold multi-tile dgemm with deep lookahead actually *uses* the
/// prefetcher (nonzero hit counter), stays bit-for-bit equal to the
/// prefetch-off engine — and a warm repeat still moves zero host
/// bytes, prefetch or not.
#[test]
fn cold_run_scores_prefetch_hits_and_warm_run_moves_no_host_bytes() {
    let ctx = Context::new(2).with_arena(8 << 20).with_tile(32).with_prefetch(Some(8));
    let n = 192;
    let mut p = Prng::new(660);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let mut c = vec![0.0; n * n];
    let rep1 =
        api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n)
            .unwrap();
    assert!(
        rep1.transfers.prefetch_hits > 0,
        "a cold 6x6-tile dgemm with depth-8 lookahead must serve some acquires from \
         prefetched tiles (got {:?})",
        rep1.transfers
    );

    let mut want = vec![0.0; n * n];
    api::dgemm(&serial_ctx(), Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want, n)
        .unwrap();
    assert_eq!(c, want, "prefetch-on cold run diverged from the prefetch-off engine");

    // Warm repeat: A and B tiles are resident, beta == 0 so C is never
    // read — the call must move zero bytes from the host even with the
    // prefetcher walking the lookahead window.
    let rep2 =
        api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n)
            .unwrap();
    assert_eq!(c, want);
    assert_eq!(
        rep2.transfers.host_reads,
        [0, 0, 0],
        "warm call must be served entirely from the device caches"
    );
}

/// Under real arena pressure the prefetcher must yield: TTL pins
/// expire (or the OOM retry flushes them) so demand fills always win,
/// the run completes without wedging, and the result is still
/// bit-for-bit the prefetch-off answer. The engagement assertion
/// (hits + wasted > 0) pins that the prefetcher did run before the
/// headroom gate closed — this workload is ~2.4x the per-device arena.
#[test]
fn prefetch_ttl_yields_under_arena_pressure() {
    let n = 320; // 10x10 grid of 8 KiB tiles: ~2.4 MiB of operands
    let ctx = Context::new(2).with_arena(1 << 20).with_tile(32).with_prefetch(Some(16));
    let mut p = Prng::new(670);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let c0 = rand(&mut p, n * n);
    let mut c = c0.clone();
    let rep = api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.1, &a, n, &b, n, -0.3, &mut c, n)
        .unwrap();
    assert!(
        rep.transfers.prefetch_hits + rep.transfers.prefetch_wasted > 0,
        "the prefetcher must have engaged before pressure gated it (got {:?})",
        rep.transfers
    );
    let serial =
        Context::new(2).with_arena(1 << 20).with_tile(32).with_persistent(false).with_prefetch(Some(0));
    let mut want = c0.clone();
    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.1, &a, n, &b, n, -0.3, &mut want, n)
        .unwrap();
    assert_eq!(c, want, "pressure-gated prefetch changed the result");
}

/// The latch protocol, raced directly: four threads demand the same
/// cold tile on one device. Exactly one gets a `Fill` ticket and moves
/// the bytes off-lock; the rest get `InFlight` (or `Ready` if they
/// arrive after completion) and consume the same block as a hit.
#[test]
fn latch_contention_one_fill_everyone_else_waits() {
    let set = Arc::new(Mutex::new(TileCacheSet::new(
        &[1 << 16, 1 << 16],
        vec![vec![1], vec![0]],
        AllocStrategy::FastHeap,
    )));
    let key = TileKey::synthetic(0x1000, MatId::A, 0, 0);
    let barrier = Arc::new(Barrier::new(4));
    let fills = Arc::new(AtomicUsize::new(0));
    let hits = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for _ in 0..4 {
            let (set, barrier, fills, hits) =
                (set.clone(), barrier.clone(), fills.clone(), hits.clone());
            s.spawn(move || {
                barrier.wait();
                // The guard drops at the end of this statement — the
                // classify step is the only time the cache lock is held.
                let got = set.lock().unwrap().acquire_async(0, key, 4096).expect("arena fits");
                match got {
                    AsyncAcquire::Fill(t) => {
                        assert!(matches!(t.source, Source::Host), "no holders anywhere yet");
                        // Simulated off-lock copy: everyone else must be
                        // parked on the latch, not spinning on the lock.
                        std::thread::sleep(std::time::Duration::from_millis(5));
                        let live = set.lock().unwrap().complete_fill(0, &key, t.peer_src());
                        assert!(live, "nothing invalidated this block mid-fill");
                        fills.fetch_add(1, Ordering::SeqCst);
                    }
                    AsyncAcquire::InFlight { latch, .. } => {
                        assert!(latch.wait(), "the fill succeeded; waiters must see ready");
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                    AsyncAcquire::Ready(acq) => {
                        assert!(matches!(acq.source, Source::L1));
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
        }
    });
    assert_eq!(fills.load(Ordering::SeqCst), 1, "exactly one racer may own the copy");
    assert_eq!(hits.load(Ordering::SeqCst), 3, "the other three consume the same block");
    // All four took reader pins; the block frees once they release.
    let mut set = set.lock().unwrap();
    for _ in 0..4 {
        set.release(0, &key);
    }
}

/// Cross-device race on one key: while device 0's copy is mid-flight,
/// device 1 must get its own independent *host* fill — a pending block
/// is never selected as a P2P source. Once device 0 latches ready, it
/// becomes a legitimate peer source for later keys.
#[test]
fn pending_block_is_never_a_peer_source() {
    let mut set = TileCacheSet::new(
        &[1 << 16, 1 << 16],
        vec![vec![1], vec![0]],
        AllocStrategy::FastHeap,
    );
    let key = TileKey::synthetic(0x2000, MatId::B, 1, 2);

    let t0 = match set.acquire_async(0, key, 4096) {
        Some(AsyncAcquire::Fill(t)) => t,
        other => panic!("cold acquire must be a fill, got {other:?}"),
    };
    // Device 1 wants the same tile while device 0 is still copying.
    match set.acquire_async(1, key, 4096) {
        Some(AsyncAcquire::Fill(t1)) => {
            assert!(
                matches!(t1.source, Source::Host),
                "a block mid-fill must not be served over P2P (got {:?})",
                t1.source
            );
            assert!(set.complete_fill(1, &key, t1.peer_src()));
        }
        other => panic!("expected an independent host fill, got {other:?}"),
    }
    assert!(set.complete_fill(0, &key, t0.peer_src()));
    set.release(0, &key);
    set.release(1, &key);

    // Control: once a holder is *ready*, the async path does plan P2P.
    let key2 = TileKey::synthetic(0x3000, MatId::A, 0, 0);
    let t2 = match set.acquire_async(0, key2, 4096) {
        Some(AsyncAcquire::Fill(t)) => t,
        other => panic!("cold acquire must be a fill, got {other:?}"),
    };
    assert!(set.complete_fill(0, &key2, t2.peer_src()));
    match set.acquire_async(1, key2, 4096) {
        Some(AsyncAcquire::Fill(t)) => {
            assert!(
                matches!(t.source, Source::Peer { src: 0, .. }),
                "ready holder must be preferred over a host read (got {:?})",
                t.source
            );
            assert!(set.complete_fill(1, &key2, t.peer_src()));
        }
        other => panic!("expected a P2P fill, got {other:?}"),
    }
    set.release(0, &key2);
    set.release(1, &key2);
}
