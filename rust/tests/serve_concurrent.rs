//! Concurrency guarantees of the multi-tenant resident runtime: N
//! client threads issuing interleaved mixed-routine calls must get
//! results bit-for-bit identical to serial execution — on disjoint
//! buffers (jobs overlap on the devices) and on deliberately-aliasing
//! buffers (in-place chains and cross-call read-after-write, ordered
//! by admission dependencies and invalidation epochs).
//!
//! Run under both the default test harness and `RUST_TEST_THREADS=1`
//! (CI does both): the scheduler's fairness picker is deterministic,
//! so single-threading the harness shakes out ordering assumptions
//! rather than changing coverage.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::{self, Context};
use blasx::hostblas;
use blasx::util::prng::Prng;

fn serve_ctx() -> Context {
    Context::new(2).with_arena(8 << 20).with_tile(32)
}

fn rand(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

/// A well-conditioned upper triangle for TRSM.
fn upper_tri(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut a = rand(p, n * n);
    for x in a.iter_mut() {
        *x *= 0.5 / (n as f64).sqrt();
    }
    for i in 0..n {
        a[i * n + i] = 2.0;
    }
    a
}

/// One client's workload: an interleaved dgemm / dsyrk / dtrsm
/// sequence on private buffers, with a deliberate intra-client
/// aliasing chain — the dgemm writes `c`, the dtrsm then solves in
/// place on the same `c` (read-after-write through the epoch
/// registry), twice over. Returns the final `c` and the syrk output.
fn client_workload(ctx: &Context, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let (m, n, k) = (96, 64, 48);
    let mut p = Prng::new(seed);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let tri = upper_tri(&mut p, m);
    let sa = rand(&mut p, n * k);
    let mut c = vec![0.0; m * n];
    let mut sc = rand(&mut p, n * n);
    // Fresh input allocations: a finished client's freed buffers may be
    // handed to a later client at the same address, so declare them per
    // the warm runtime's liveness contract (no-op on one-shot contexts;
    // outputs c/sc are epoch-bumped automatically at admission).
    ctx.invalidate_host(&a);
    ctx.invalidate_host(&b);
    ctx.invalidate_host(&tri);
    ctx.invalidate_host(&sa);
    for _ in 0..2 {
        api::dgemm(ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
            .unwrap();
        api::syrk(ctx, Uplo::Lower, Trans::No, n, k, 0.7, &sa, n, 0.4, &mut sc, n).unwrap();
        // aliasing: c is the dgemm's output AND the trsm's in/out
        api::trsm(ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0, &tri, m, &mut c, m)
            .unwrap();
    }
    (c, sc)
}

/// The tentpole concurrency property: N clients hammering one shared
/// persistent runtime with mixed routines produce results bit-for-bit
/// identical to each client running serially on a fresh one-shot
/// engine.
#[test]
fn concurrent_mixed_routines_match_serial_bit_for_bit() {
    let ctx = serve_ctx();
    let results: Vec<(u64, Vec<f64>, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4u64)
            .map(|seed| {
                let ctx = ctx.clone();
                scope.spawn(move || {
                    let (c, sc) = client_workload(&ctx, 500 + seed);
                    (seed, c, sc)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // 4 clients × 2 rounds × 3 calls
    assert_eq!(ctx.runtime_calls(), 24, "every call must flow through the resident runtime");
    assert_eq!(ctx.jobs_in_flight(), 0);
    for (seed, c, sc) in results {
        let serial = serve_ctx().with_persistent(false);
        let (want_c, want_sc) = client_workload(&serial, 500 + seed);
        assert_eq!(c, want_c, "client {seed}: concurrent dgemm/trsm chain diverged from serial");
        assert_eq!(sc, want_sc, "client {seed}: concurrent syrk diverged from serial");
    }
}

/// Scope-async jobs on disjoint buffers are admitted concurrently, may
/// be waited out of order, and each lands the exact blocking-call
/// result.
#[test]
fn async_jobs_overlap_and_complete_out_of_order() {
    let ctx = serve_ctx();
    let (m, n, k) = (64, 64, 48);
    let jobs = 6;
    let mut p = Prng::new(900);
    let abufs: Vec<Vec<f64>> = (0..jobs).map(|_| rand(&mut p, m * k)).collect();
    let bbufs: Vec<Vec<f64>> = (0..jobs).map(|_| rand(&mut p, k * n)).collect();
    let mut cbufs: Vec<Vec<f64>> = (0..jobs).map(|_| vec![0.0; m * n]).collect();

    ctx.scope(|s| {
        let handles: Vec<_> = cbufs
            .iter_mut()
            .enumerate()
            .map(|(i, c)| {
                let (ra, rb) = (s.input(&abufs[i]), s.input(&bbufs[i]));
                let rc = s.buffer(c);
                s.dgemm(Trans::No, Trans::No, m, n, k, 1.0, ra, m, rb, k, 0.0, rc, m).unwrap()
            })
            .collect();
        assert!(ctx.jobs_in_flight() <= jobs);
        // Wait newest-first: completion order must not matter.
        for h in handles.into_iter().rev() {
            h.wait().unwrap();
        }
        Ok(())
    })
    .unwrap();
    assert_eq!(ctx.runtime_calls(), jobs);
    for i in 0..jobs {
        let mut want = vec![0.0; m * n];
        hostblas::gemm_blocked(
            Trans::No, Trans::No, m, n, k, 1.0, &abufs[i], m, &bbufs[i], k, 0.0, &mut want, m,
        );
        let diff =
            cbufs[i].iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "job {i}: {diff}");
    }
}

/// A blocking read-after-write chain (call 2 reads call 1's output —
/// the epoch-dependency path) stays bit-for-bit correct while an
/// unrelated scope-async job churns the same devices and caches.
#[test]
fn raw_chain_stays_coherent_under_concurrent_load() {
    let ctx = serve_ctx();
    let n = 64;
    let mut p = Prng::new(901);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let d = rand(&mut p, n * n);
    // background tenant: a larger independent job
    let big_a = rand(&mut p, 160 * 160);
    let big_b = rand(&mut p, 160 * 160);
    let mut big_c = vec![0.0; 160 * 160];
    let mut x = vec![0.0; n * n];
    let mut e = vec![0.0; n * n];
    ctx.scope(|s| {
        let (rba, rbb) = (s.input(&big_a), s.input(&big_b));
        let rbc = s.buffer(&mut big_c);
        let bg = s.dgemm(
            Trans::No, Trans::No, 160, 160, 160, 1.0, rba, 160, rbb, 160, 0.0, rbc, 160,
        )?;

        // foreground chain: x := a*b, then e := x*d (reads the buffer
        // the first call just rewrote — served through the bumped
        // epoch, never from stale tiles); blocking calls interleave
        // freely with the in-flight scope job.
        api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut x, n)?;
        api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &x, n, &d, n, 0.0, &mut e, n)?;
        bg.wait()?;
        Ok(())
    })
    .unwrap();

    let serial = serve_ctx().with_persistent(false);
    let mut want_x = vec![0.0; n * n];
    let mut want_e = vec![0.0; n * n];
    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want_x, n)
        .unwrap();
    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.0, &want_x, n, &d, n, 0.0, &mut want_e, n)
        .unwrap();
    assert_eq!(x, want_x);
    assert_eq!(e, want_e, "RAW chain diverged under concurrent load");

    let mut want_big = vec![0.0; 160 * 160];
    hostblas::gemm_blocked(
        Trans::No, Trans::No, 160, 160, 160, 1.0, &big_a, 160, &big_b, 160, 0.0, &mut want_big, 160,
    );
    let diff = big_c.iter().zip(&want_big).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
    assert!(diff < 1e-9, "background tenant corrupted: {diff}");
}

/// Clients sharing one input matrix (read-read aliasing — the good
/// kind) serve it from the warm tile caches: after a warm-up call, no
/// client re-reads A from the host.
#[test]
fn concurrent_clients_share_warm_input_tiles() {
    let ctx = serve_ctx();
    let (m, n, k) = (64, 64, 64);
    let mut p = Prng::new(902);
    let shared_a = rand(&mut p, m * k);
    // warm A's tiles (private B/C so only A stays resident-relevant)
    {
        let b = rand(&mut p, k * n);
        let mut c = vec![0.0; m * n];
        api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &shared_a, m, &b, k, 0.0, &mut c, m)
            .unwrap();
    }
    let a_reads: usize = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..3u64)
            .map(|seed| {
                let ctx = ctx.clone();
                let shared_a = &shared_a;
                scope.spawn(move || {
                    let mut p = Prng::new(700 + seed);
                    let b = rand(&mut p, k * n);
                    let mut c = vec![0.0; m * n];
                    ctx.invalidate_host(&b);
                    let rep = api::dgemm(
                        &ctx, Trans::No, Trans::No, m, n, k, 1.0, shared_a, m, &b, k, 0.0,
                        &mut c, m,
                    )
                    .unwrap();
                    // bit-for-bit vs the serial engine (same tile
                    // decomposition), tolerance vs the host oracle
                    let fresh = serve_ctx().with_persistent(false);
                    let mut want = vec![0.0; m * n];
                    api::dgemm(
                        &fresh, Trans::No, Trans::No, m, n, k, 1.0, shared_a, m, &b, k, 0.0,
                        &mut want, m,
                    )
                    .unwrap();
                    assert_eq!(c, want, "client {seed}");
                    rep.transfers.host_reads[0]
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    assert_eq!(a_reads, 0, "shared A must be served from the warm caches for every client");
}

/// Stress: many clients × many small jobs; every result verified. The
/// scheduler must neither starve, deadlock, nor cross-contaminate.
#[test]
fn many_clients_many_jobs_stress() {
    let ctx = serve_ctx();
    std::thread::scope(|scope| {
        for seed in 0..6u64 {
            let ctx = ctx.clone();
            scope.spawn(move || {
                let (m, n, k) = (48, 40, 33);
                let mut p = Prng::new(300 + seed);
                for _ in 0..4 {
                    let a = rand(&mut p, m * k);
                    let b = rand(&mut p, k * n);
                    let c0 = rand(&mut p, m * n);
                    ctx.invalidate_host(&a);
                    ctx.invalidate_host(&b);
                    let mut c = c0.clone();
                    api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.3, &a, m, &b, k, -0.7, &mut c, m)
                        .unwrap();
                    let fresh = serve_ctx().with_persistent(false);
                    let mut want = c0.clone();
                    api::dgemm(
                        &fresh, Trans::No, Trans::No, m, n, k, 1.3, &a, m, &b, k, -0.7, &mut want, m,
                    )
                    .unwrap();
                    assert_eq!(c, want, "client {seed}: diverged from serial");
                }
            });
        }
    });
    assert_eq!(ctx.runtime_calls(), 24);
    assert_eq!(ctx.jobs_in_flight(), 0);
}

/// Mixed f32/f64 tenants share the byte-granular fleet concurrently.
#[test]
fn mixed_dtype_tenants_overlap() {
    let ctx = serve_ctx();
    std::thread::scope(|scope| {
        let ctx_d = ctx.clone();
        scope.spawn(move || {
            let (m, n, k) = (64, 48, 40);
            let mut p = Prng::new(41);
            let a = rand(&mut p, m * k);
            let b = rand(&mut p, k * n);
            let mut c = vec![0.0; m * n];
            api::dgemm(&ctx_d, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
                .unwrap();
            let mut want = vec![0.0; m * n];
            hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut want, m);
            let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
            assert!(diff < 1e-10, "f64 tenant diverged: {diff}");
        });
        let ctx_s = ctx.clone();
        scope.spawn(move || {
            let (m, n, k) = (56, 56, 56);
            let mut p = Prng::new(42);
            let mut a = vec![0.0f32; m * k];
            let mut b = vec![0.0f32; k * n];
            p.fill_f32(&mut a, -1.0, 1.0);
            p.fill_f32(&mut b, -1.0, 1.0);
            let mut c = vec![0.0f32; m * n];
            api::sgemm(&ctx_s, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
                .unwrap();
            let mut want = vec![0.0f32; m * n];
            hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0f32, &a, m, &b, k, 0.0, &mut want, m);
            let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(diff < 1e-3, "f32 tenant diverged: {diff}");
        });
    });
    assert_eq!(ctx.runtime_calls(), 2);
}
