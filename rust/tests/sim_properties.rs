//! Property tests over the scheduling runtime (proptest-style via
//! `util::prop::Cases`): every policy, routine, machine shape and knob
//! combination must complete all tasks, keep the trace self-consistent,
//! conserve communication volume, and be fully deterministic.

use blasx::api::types::Routine;
use blasx::api::Dtype;
use blasx::coordinator::{run_sim, square_workload, Policy, RunConfig};
use blasx::sim::{everest, makalu, toy, Machine};
use blasx::trace::EvKind;
use blasx::util::prop::Cases;
use blasx::util::prng::Prng;

fn random_machine(rng: &mut Prng) -> Machine {
    match rng.below(3) {
        0 => everest(rng.range(1, 3)),
        1 => makalu(rng.range(1, 4)),
        _ => toy(rng.range(1, 4), (16 + rng.below(64)) << 20),
    }
}

fn random_cfg(rng: &mut Prng, t: usize) -> RunConfig {
    RunConfig {
        t,
        n_streams: rng.range(1, 4),
        rs_capacity: rng.range(4, 11),
        policy: Policy::Blasx,
        use_cpu: rng.chance(0.3),
        work_stealing: rng.chance(0.8),
        k_chunk: rng.range(1, 7),
        jitter: if rng.chance(0.5) { 0.1 } else { 0.0 },
        ..Default::default()
    }
}

#[test]
fn blasx_completes_everything_and_conserves_traffic() {
    Cases::new(60).run("blasx_completes", |rng| {
        let t = [64, 128, 256][rng.below(3)];
        let n = t * rng.range(2, 7);
        let routine = Routine::ALL[rng.below(6)];
        let machine = random_machine(rng);
        let cfg = random_cfg(rng, t);
        let w = square_workload(routine, n, t, Dtype::F64);
        let n_tasks = w.ts.tasks.len();
        let rep = run_sim(&cfg, &machine, &w);

        if !rep.feasible {
            return Err("BLASX must always be feasible (out-of-core)".into());
        }
        if rep.tasks_per_worker.iter().sum::<usize>() != n_tasks {
            return Err(format!(
                "{routine:?} N={n} T={t}: {:?} != {n_tasks} tasks",
                rep.tasks_per_worker
            ));
        }
        if !(rep.makespan > 0.0) {
            return Err("non-positive makespan".into());
        }
        // trace events inside [0, makespan], with sane geometry
        for e in &rep.trace.events {
            if e.start < -1e-12 || e.end > rep.makespan + 1e-9 || e.end < e.start {
                return Err(format!("bad event {e:?} (makespan {})", rep.makespan));
            }
        }
        // conservation: every GPU-executed task's C tile is written back
        // exactly once => total D2H equals the covered C bytes (the CPU
        // worker writes host RAM directly, so with use_cpu it's <=).
        let d2h: f64 = (0..machine.devices.len())
            .map(|d| rep.trace.bytes(d, EvKind::D2h))
            .sum();
        let c_bytes: f64 = w.ts.tasks.iter().map(|t| (t.m * t.n * 8) as f64).sum();
        if cfg.use_cpu {
            if d2h > c_bytes * (1.0 + 1e-9) {
                return Err(format!("D2H {d2h} > covered C bytes {c_bytes}"));
            }
        } else if (d2h - c_bytes).abs() > 1e-6 * c_bytes {
            return Err(format!("D2H {d2h} != covered C bytes {c_bytes}"));
        }
        Ok(())
    });
}

#[test]
fn baselines_complete_everything() {
    Cases::new(40).run("baselines_complete", |rng| {
        let t = 128;
        let n = t * rng.range(2, 6);
        let routine = Routine::ALL[rng.below(6)];
        let machine = random_machine(rng);
        let policy =
            [Policy::CublasXt, Policy::Magma, Policy::SuperMatrix, Policy::Parsec][rng.below(4)];
        let cfg = RunConfig { t, policy, ..random_cfg(rng, t) };
        let w = square_workload(routine, n, t, Dtype::F64);
        let rep = run_sim(&cfg, &machine, &w);
        if !rep.feasible {
            return Ok(()); // in-core gates may fire on toy machines
        }
        if rep.tasks_per_worker.iter().sum::<usize>() != w.ts.tasks.len() {
            return Err(format!(
                "{policy:?} {routine:?}: {:?} != {}",
                rep.tasks_per_worker,
                w.ts.tasks.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn simulation_is_deterministic() {
    Cases::new(12).run("determinism", |rng| {
        let t = 128;
        let n = t * rng.range(2, 6);
        let routine = Routine::ALL[rng.below(6)];
        let machine = everest(rng.range(1, 3));
        let cfg = random_cfg(rng, t);
        let w = square_workload(routine, n, t, Dtype::F64);
        let a = run_sim(&cfg, &machine, &w);
        let b = run_sim(&cfg, &machine, &w);
        if a.makespan != b.makespan {
            return Err(format!("makespan {} vs {}", a.makespan, b.makespan));
        }
        if a.tasks_per_worker != b.tasks_per_worker {
            return Err("task split differs".into());
        }
        if a.trace.events.len() != b.trace.events.len() {
            return Err("event count differs".into());
        }
        Ok(())
    });
}

#[test]
fn p2p_only_between_switch_peers() {
    Cases::new(25).run("p2p_topology", |rng| {
        let t = 128;
        let n = t * rng.range(3, 7);
        let machine = everest(3); // P2P pair is (1, 2) only
        let cfg = random_cfg(rng, t);
        let w = square_workload(Routine::Gemm, n, t, Dtype::F64);
        let rep = run_sim(&cfg, &machine, &w);
        // device 0 has no switch peer: must never receive P2P traffic
        if rep.trace.bytes(0, EvKind::P2p) != 0.0 {
            return Err("GPU0 received P2P traffic without a switch peer".into());
        }
        Ok(())
    });
}

#[test]
fn more_devices_never_lose_badly() {
    // Weak-scaling sanity: on the homogeneous Everest, 3 GPUs must beat
    // 1 GPU clearly once the problem is large enough.
    let t = 256;
    let n = 4096;
    let w = square_workload(Routine::Gemm, n, t, Dtype::F64);
    let cfg = RunConfig { t, ..Default::default() };
    let m1 = run_sim(&cfg, &everest(1), &w);
    let m3 = run_sim(&cfg, &everest(3), &w);
    assert!(
        m3.makespan < m1.makespan * 0.55,
        "3 GPUs {:.4}s vs 1 GPU {:.4}s",
        m3.makespan,
        m1.makespan
    );
}

#[test]
fn stealing_disabled_still_completes() {
    let t = 128;
    let w = square_workload(Routine::Syr2k, 1024, t, Dtype::F64);
    let cfg = RunConfig { t, work_stealing: false, ..Default::default() };
    let rep = run_sim(&cfg, &makalu(4), &w);
    assert!(rep.feasible);
    assert_eq!(rep.tasks_per_worker.iter().sum::<usize>(), w.ts.tasks.len());
    assert!(rep.steals.iter().all(|&s| s == 0));
}

#[test]
fn cpu_worker_contributes_on_demand() {
    let t = 256;
    let n = 4096;
    let w = square_workload(Routine::Gemm, n, t, Dtype::F64);
    let base = {
        let cfg = RunConfig { t, use_cpu: false, ..Default::default() };
        run_sim(&cfg, &everest(2), &w)
    };
    let cpu = {
        let cfg = RunConfig { t, use_cpu: true, ..Default::default() };
        run_sim(&cfg, &everest(2), &w)
    };
    // CPU worker appears as an extra entry and takes at least one task
    assert_eq!(cpu.tasks_per_worker.len(), base.tasks_per_worker.len() + 1);
    assert!(*cpu.tasks_per_worker.last().unwrap() > 0, "{:?}", cpu.tasks_per_worker);
    // and it must not hurt the makespan materially
    assert!(cpu.makespan <= base.makespan * 1.05);
}
