//! PR-8 acceptance properties of adaptive dispatch and per-geometry
//! cache generations: tenants alternating two tile sizes through one
//! shared runtime stay warm (no barrier jobs, no global purges — those
//! code paths are gone, and these tests pin the behaviour that made
//! deleting them sound), generations are isolated, a saved profile
//! reproduces identical choices after a load round-trip, and
//! host-placed calls stay admission-ordered through the epoch
//! registry.

use blasx::api::types::{Dtype, Trans};
use blasx::api::{self, Context};
use blasx::coordinator::real_engine::TransferStats;
use blasx::dispatch::{shape_key, Choice, Dispatcher, Placement, Profile};
use blasx::hostblas;
use blasx::util::prng::Prng;

fn base_ctx() -> Context {
    Context::new(2).with_arena(8 << 20).with_tile(64)
}

fn rand(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

fn max_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// The tentpole acceptance run: two tenants alternating DIFFERENT tile
/// sizes through one shared resident runtime. Pre-PR-8 every switch
/// was a barrier job plus a global cache purge, so alternation
/// thrashed: each call re-fetched everything. With `t` in the tile
/// key, each geometry is its own cache generation — after one cold
/// call per tenant, every later call is transfer-free, and both
/// tenants match a serial one-shot engine bit-for-bit.
#[test]
fn alternating_tile_sizes_stay_warm_with_no_purges() {
    let ctx64 = base_ctx();
    // `with_tile` keeps the shared runtime slot on purpose: mixed
    // geometries coexist in one cache.
    let ctx96 = ctx64.clone().with_tile(96);
    let (m, n, k) = (128, 128, 128);
    let mut p = Prng::new(810);
    let a64 = rand(&mut p, m * k);
    let b64 = rand(&mut p, k * n);
    let a96 = rand(&mut p, m * k);
    let b96 = rand(&mut p, k * n);
    let mut c64 = vec![0.0; m * n];
    let mut c96 = vec![0.0; m * n];

    // One cold call per tenant populates its generation.
    let cold64 = api::dgemm(&ctx64, Trans::No, Trans::No, m, n, k, 1.0, &a64, m, &b64, k, 0.0, &mut c64, m)
        .unwrap();
    let cold96 = api::dgemm(&ctx96, Trans::No, Trans::No, m, n, k, 1.0, &a96, m, &b96, k, 0.0, &mut c96, m)
        .unwrap();
    assert!(cold64.transfers.input_host_reads() > 0);
    assert!(cold96.transfers.input_host_reads() > 0);
    let first64 = c64.clone();
    let first96 = c96.clone();

    // Alternate. Every call after the cold pair must be transfer-free:
    // a surviving purge path would zero one generation on each switch
    // and show up here as host re-reads.
    for round in 0..3 {
        let r64 = api::dgemm(
            &ctx64, Trans::No, Trans::No, m, n, k, 1.0, &a64, m, &b64, k, 0.0, &mut c64, m,
        )
        .unwrap();
        assert_eq!(
            r64.transfers.input_host_reads(),
            0,
            "round {round}: t=64 tenant purged by the t=96 tenant: {:?}",
            r64.transfers
        );
        assert!(r64.transfers.l1_hits + r64.transfers.peer_copies > 0, "round {round}");
        let r96 = api::dgemm(
            &ctx96, Trans::No, Trans::No, m, n, k, 1.0, &a96, m, &b96, k, 0.0, &mut c96, m,
        )
        .unwrap();
        assert_eq!(
            r96.transfers.input_host_reads(),
            0,
            "round {round}: t=96 tenant purged by the t=64 tenant: {:?}",
            r96.transfers
        );
        assert_eq!(c64, first64, "round {round}: warm t=64 numerics drifted");
        assert_eq!(c96, first96, "round {round}: warm t=96 numerics drifted");
    }
    assert_eq!(ctx64.runtime_calls(), 8, "both tenants share one resident runtime");
    assert_eq!(ctx64.jobs_in_flight(), 0);

    // Bit-for-bit vs a serial one-shot engine at each geometry.
    for (t, a, b, got) in [(64, &a64, &b64, &c64), (96, &a96, &b96, &c96)] {
        let fresh = Context::new(2).with_arena(8 << 20).with_tile(t).with_persistent(false);
        let mut want = vec![0.0; m * n];
        api::dgemm(&fresh, Trans::No, Trans::No, m, n, k, 1.0, a, m, b, k, 0.0, &mut want, m)
            .unwrap();
        assert_eq!(got, &want, "t={t}: mixed-tile serve diverged from serial");
    }
}

/// The same property under real concurrency: mixed-tile tenants hammer
/// the shared runtime from separate threads, every result verified
/// against a serial one-shot engine at the same geometry.
#[test]
fn mixed_tile_tenants_overlap_concurrently() {
    let ctx64 = base_ctx();
    let ctx96 = ctx64.clone().with_tile(96);
    std::thread::scope(|scope| {
        for (t, ctx, seed) in [(64usize, ctx64.clone(), 820u64), (96, ctx96.clone(), 821)] {
            scope.spawn(move || {
                let (m, n, k) = (128, 96, 112);
                let mut p = Prng::new(seed);
                let a = rand(&mut p, m * k);
                let b = rand(&mut p, k * n);
                let c0 = rand(&mut p, m * n);
                ctx.invalidate_host(&a);
                ctx.invalidate_host(&b);
                for call in 0..3 {
                    let mut c = c0.clone();
                    api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.2, &a, m, &b, k, -0.4, &mut c, m)
                        .unwrap();
                    let fresh =
                        Context::new(2).with_arena(8 << 20).with_tile(t).with_persistent(false);
                    let mut want = c0.clone();
                    api::dgemm(
                        &fresh, Trans::No, Trans::No, m, n, k, 1.2, &a, m, &b, k, -0.4, &mut want, m,
                    )
                    .unwrap();
                    assert_eq!(c, want, "t={t} call {call}: diverged from serial");
                }
            });
        }
    });
    assert_eq!(ctx64.runtime_calls(), 6);
    assert_eq!(ctx64.jobs_in_flight(), 0);
}

/// Cache generations are keyed by tile size: one host buffer warmed at
/// t=64 is COLD at t=96 (separate generation, fetched fresh) and the
/// t=96 traffic leaves the t=64 generation untouched.
#[test]
fn tile_generations_are_isolated() {
    let ctx64 = base_ctx();
    let ctx96 = ctx64.clone().with_tile(96);
    let n = 128;
    let mut p = Prng::new(830);
    let shared_a = rand(&mut p, n * n);
    let b64 = rand(&mut p, n * n);
    let b96 = rand(&mut p, n * n);
    let mut c = vec![0.0; n * n];

    // Warm A's t=64 generation.
    api::dgemm(&ctx64, Trans::No, Trans::No, n, n, n, 1.0, &shared_a, n, &b64, n, 0.0, &mut c, n)
        .unwrap();
    let warm = api::dgemm(
        &ctx64, Trans::No, Trans::No, n, n, n, 1.0, &shared_a, n, &b64, n, 0.0, &mut c, n,
    )
    .unwrap();
    assert_eq!(warm.transfers.host_reads[0], 0, "A must be warm at t=64");

    // The SAME buffer through the t=96 tenant: its own generation,
    // fetched from the host even though A is resident at t=64.
    let gen96 = api::dgemm(
        &ctx96, Trans::No, Trans::No, n, n, n, 1.0, &shared_a, n, &b96, n, 0.0, &mut c, n,
    )
    .unwrap();
    assert!(
        gen96.transfers.host_reads[0] > 0,
        "t=96 generation of A must be populated independently: {:?}",
        gen96.transfers
    );

    // ...and populating it did not disturb the t=64 generation.
    let still_warm = api::dgemm(
        &ctx64, Trans::No, Trans::No, n, n, n, 1.0, &shared_a, n, &b64, n, 0.0, &mut c, n,
    )
    .unwrap();
    assert_eq!(
        still_warm.transfers.host_reads[0],
        0,
        "t=96 traffic evicted the t=64 generation: {:?}",
        still_warm.transfers
    );

    let mut want = vec![0.0; n * n];
    hostblas::gemm_blocked(Trans::No, Trans::No, n, n, n, 1.0, &shared_a, n, &b64, n, 0.0, &mut want, n);
    assert!(max_diff(&c, &want) < 1e-10);
}

/// `Profile::save` → `Profile::load` reproduces byte-identical
/// dispatch: the loaded table equals the saved one and a dispatcher
/// built from each makes the same choice for every probed shape —
/// including heuristic fallbacks for shapes the profile doesn't cover.
#[test]
fn profile_roundtrip_reproduces_identical_choices() {
    let mut prof = Profile::new();
    prof.set(
        shape_key("gemm", Dtype::F64, 300, 300, 300),
        Choice { t: 128, kernel_threads: 3, mt_cutoff: Some(2.5e6), place: Placement::Device },
    );
    prof.set(
        shape_key("gemm", Dtype::F64, 48, 48, 48),
        Choice { t: 64, kernel_threads: 2, mt_cutoff: None, place: Placement::Host },
    );
    prof.set(
        shape_key("gemm", Dtype::F32, 500, 500, 500),
        Choice { t: 256, kernel_threads: 1, mt_cutoff: None, place: Placement::Device },
    );
    prof.set(
        shape_key("syrk", Dtype::F64, 200, 200, 100),
        Choice { t: 64, kernel_threads: 4, mt_cutoff: Some(1e6), place: Placement::Device },
    );

    let path = std::env::temp_dir().join(format!("blasx_profile_rt_{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    prof.save(&path).unwrap();
    let loaded = Profile::load(&path).unwrap();
    assert_eq!(loaded, prof, "profile changed across save/load");

    let saved_d = Dispatcher::from_profile(prof);
    let loaded_d = Dispatcher::from_profile(loaded);
    let base = Choice { t: 256, kernel_threads: 1, mt_cutoff: None, place: Placement::Device };
    for routine in ["gemm", "syrk", "trsm"] {
        for dt in [Dtype::F32, Dtype::F64] {
            for &(m, n, k) in
                &[(48, 48, 48), (64, 64, 64), (100, 90, 110), (300, 300, 300), (500, 500, 500), (1000, 40, 7)]
            {
                assert_eq!(
                    saved_d.choose(routine, dt, m, n, k, &base),
                    loaded_d.choose(routine, dt, m, n, k, &base),
                    "{routine}/{dt:?} {m}x{n}x{k}: choice changed across the round-trip"
                );
            }
        }
    }

    // The same guarantee through the Context builders the CLI uses.
    let from_mem = base_ctx().with_profile(Profile::load(&path).unwrap());
    let from_file = base_ctx().with_profile_file(&path).unwrap();
    let (dm, df) = (from_mem.dispatcher().unwrap(), from_file.dispatcher().unwrap());
    assert_eq!(
        dm.choose("gemm", Dtype::F64, 300, 300, 300, &base),
        df.choose("gemm", Dtype::F64, 300, 300, 300, &base),
    );
    let _ = std::fs::remove_file(&path);
}

/// Host-placed calls flow through the SAME admission/epoch machinery
/// as tiled ones: a host-placed GEMM that rewrites a buffer whose
/// tiles are warm on the devices must epoch-bump it, so the next tiled
/// reader re-fetches instead of serving stale tiles.
#[test]
fn host_placement_epoch_bumps_its_output() {
    let mut prof = Profile::new();
    // 48^3 lands in bucket m6n6k6 → forced host placement; the 96-row
    // device calls land in m7n6k6, which the profile does not cover,
    // so they take the normal tiled path at the context geometry.
    prof.set(
        shape_key("gemm", Dtype::F64, 48, 48, 48),
        Choice { t: 64, kernel_threads: 1, mt_cutoff: None, place: Placement::Host },
    );
    let ctx = base_ctx().with_profile(prof);
    let (m, n, k) = (96, 48, 48);
    let mut p = Prng::new(840);
    let a1 = rand(&mut p, m * k);
    let mut x = rand(&mut p, k * n);
    let a2 = rand(&mut p, k * k);
    let b2 = rand(&mut p, k * n);
    let mut y = vec![0.0; m * n];

    // Tiled call warms x's tiles (as the B operand).
    api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a1, m, &x, k, 0.0, &mut y, m).unwrap();
    let calls_before = ctx.runtime_calls();

    // Host-placed rewrite of x: admission-ordered, never staged.
    let host_rep =
        api::dgemm(&ctx, Trans::No, Trans::No, k, n, k, 1.0, &a2, k, &b2, k, 0.0, &mut x, k)
            .unwrap();
    assert_eq!(
        host_rep.transfers,
        TransferStats::default(),
        "host-placed call must not stage tiles"
    );
    assert_eq!(host_rep.tasks_per_device.iter().sum::<usize>(), 0);
    assert_eq!(ctx.runtime_calls(), calls_before + 1, "host call must flow through the runtime");
    let mut want_x = vec![0.0; k * n];
    hostblas::gemm_mt(1, Trans::No, Trans::No, k, n, k, 1.0, &a2, k, &b2, k, 0.0, &mut want_x, k);
    assert_eq!(x, want_x, "host-placed gemm diverged from the host kernel");

    // The tiled reader of the rewritten x must see the NEW values: the
    // host job's epoch bump forces a re-fetch of x's warm tiles.
    let rep = api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a1, m, &x, k, 0.0, &mut y, m)
        .unwrap();
    assert!(
        rep.transfers.host_reads[1] > 0,
        "rewritten x must be re-fetched, not served stale: {:?}",
        rep.transfers
    );
    let fresh = Context::new(2).with_arena(8 << 20).with_tile(64).with_persistent(false);
    let mut want = vec![0.0; m * n];
    api::dgemm(&fresh, Trans::No, Trans::No, m, n, k, 1.0, &a1, m, &x, k, 0.0, &mut want, m)
        .unwrap();
    assert_eq!(y, want, "tiled call after a host-placed rewrite served stale tiles");
}
