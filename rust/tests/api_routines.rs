//! API-level correctness grid: every public routine through the full
//! runtime (taskizer → scheduler → caches → kernels → write-back)
//! against the single-threaded reference, across the parameter space
//! (uplo/side/trans/diag) and awkward shapes.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::{self, Context};
use blasx::hostblas;
use blasx::util::prng::Prng;

fn ctx() -> Context {
    Context::new(2).with_arena(4 << 20).with_tile(32)
}

fn rand(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

fn diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn api_syrk_grid() {
    let ctx = ctx();
    let (n, k) = (70, 45);
    for uplo in [Uplo::Upper, Uplo::Lower] {
        for trans in [Trans::No, Trans::Yes] {
            let mut p = Prng::new(31);
            let (ar, ac) = if trans == Trans::No { (n, k) } else { (k, n) };
            let a = rand(&mut p, ar * ac);
            let mut c = rand(&mut p, n * n);
            let mut want = c.clone();
            api::syrk(&ctx, uplo, trans, n, k, 0.9, &a, ar, -0.4, &mut c, n).unwrap();
            hostblas::syrk_ref(uplo, trans, n, k, 0.9, &a, ar, -0.4, &mut want, n);
            assert!(diff(&c, &want) < 1e-10, "syrk {uplo:?} {trans:?}: {}", diff(&c, &want));
        }
    }
}

#[test]
fn api_syr2k_grid() {
    let ctx = ctx();
    let (n, k) = (64, 40);
    for uplo in [Uplo::Upper, Uplo::Lower] {
        for trans in [Trans::No, Trans::Yes] {
            let mut p = Prng::new(32);
            let (ar, ac) = if trans == Trans::No { (n, k) } else { (k, n) };
            let a = rand(&mut p, ar * ac);
            let b = rand(&mut p, ar * ac);
            let mut c = rand(&mut p, n * n);
            let mut want = c.clone();
            api::syr2k(&ctx, uplo, trans, n, k, 1.3, &a, ar, &b, ar, 0.7, &mut c, n).unwrap();
            hostblas::syr2k_ref(uplo, trans, n, k, 1.3, &a, ar, &b, ar, 0.7, &mut want, n);
            assert!(diff(&c, &want) < 1e-10, "syr2k {uplo:?} {trans:?}");
        }
    }
}

#[test]
fn api_symm_grid() {
    let ctx = ctx();
    let (m, n) = (50, 66);
    for side in [Side::Left, Side::Right] {
        for uplo in [Uplo::Upper, Uplo::Lower] {
            let mut p = Prng::new(33);
            let na = if side == Side::Left { m } else { n };
            let a = rand(&mut p, na * na);
            let b = rand(&mut p, m * n);
            let mut c = rand(&mut p, m * n);
            let mut want = c.clone();
            api::symm(&ctx, side, uplo, m, n, -1.1, &a, na, &b, m, 0.2, &mut c, m).unwrap();
            hostblas::symm_ref(side, uplo, m, n, -1.1, &a, na, &b, m, 0.2, &mut want, m);
            assert!(diff(&c, &want) < 1e-10, "symm {side:?} {uplo:?}");
        }
    }
}

#[test]
fn api_trmm_trsm_grid() {
    let ctx = ctx();
    let (m, n) = (64, 48);
    for side in [Side::Left, Side::Right] {
        for uplo in [Uplo::Upper, Uplo::Lower] {
            for ta in [Trans::No, Trans::Yes] {
                for dg in [Diag::NonUnit, Diag::Unit] {
                    let mut p = Prng::new(34);
                    let na = if side == Side::Left { m } else { n };
                    let mut a = rand(&mut p, na * na);
                    for x in a.iter_mut() {
                        *x *= 0.3 / (na as f64).sqrt();
                    }
                    for i in 0..na {
                        a[i * na + i] = 1.5;
                    }
                    // TRMM
                    let mut b = rand(&mut p, m * n);
                    let mut want = b.clone();
                    api::trmm(&ctx, side, uplo, ta, dg, m, n, 0.8, &a, na, &mut b, m).unwrap();
                    hostblas::trmm_ref(side, uplo, ta, dg, m, n, 0.8, &a, na, &mut want, m);
                    assert!(diff(&b, &want) < 1e-10, "trmm {side:?} {uplo:?} {ta:?} {dg:?}");
                    // TRSM
                    let mut b2 = rand(&mut p, m * n);
                    let mut want2 = b2.clone();
                    api::trsm(&ctx, side, uplo, ta, dg, m, n, 1.2, &a, na, &mut b2, m).unwrap();
                    hostblas::trsm_ref(side, uplo, ta, dg, m, n, 1.2, &a, na, &mut want2, m);
                    assert!(diff(&b2, &want2) < 1e-9, "trsm {side:?} {uplo:?} {ta:?} {dg:?}");
                }
            }
        }
    }
}

#[test]
fn api_degenerate_sizes() {
    let ctx = ctx();
    // 1x1, smaller than a tile, exactly one tile
    for n in [1usize, 7, 32] {
        let mut p = Prng::new(35);
        let a = rand(&mut p, n * n);
        let b = rand(&mut p, n * n);
        let mut c = rand(&mut p, n * n);
        let mut want = c.clone();
        api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 2.0, &a, n, &b, n, 3.0, &mut c, n).unwrap();
        hostblas::gemm_blocked(Trans::No, Trans::No, n, n, n, 2.0, &a, n, &b, n, 3.0, &mut want, n);
        assert!(diff(&c, &want) < 1e-10, "n={n}");
    }
}

#[test]
fn api_alpha_zero_scales_only() {
    let ctx = ctx();
    let n = 40;
    let mut p = Prng::new(36);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let mut c = rand(&mut p, n * n);
    let want: Vec<f64> = c.iter().map(|x| 0.5 * x).collect();
    api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 0.0, &a, n, &b, n, 0.5, &mut c, n).unwrap();
    assert!(diff(&c, &want) < 1e-15);
}

#[test]
fn api_beta_zero_ignores_garbage_c() {
    let ctx = ctx();
    let n = 48;
    let mut p = Prng::new(37);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    // C full of NaN must be overwritten cleanly when beta == 0
    let mut c = vec![f64::NAN; n * n];
    let mut want = vec![0.0; n * n];
    api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n).unwrap();
    hostblas::gemm_blocked(Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want, n);
    assert!(c.iter().all(|x| x.is_finite()), "NaN leaked through beta=0");
    assert!(diff(&c, &want) < 1e-10);
}
