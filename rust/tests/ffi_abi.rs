//! The C ABI exercised from Rust (the exports are ordinary functions
//! to a Rust caller): cblas_* blocking entries in both storage orders
//! against the safe path, the blasx_*_async job surface with aliasing
//! chains, and the error-reporting contract.
//!
//! Everything here shares the process-global default context (the
//! drop-in configuration: default tile/devices — these tests assume no
//! BLASX_* environment overrides, as in CI). Run under both the
//! default harness and `RUST_TEST_THREADS=1`; concurrent tests are
//! exactly the multi-tenant traffic the default context exists for.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::{self, Context};
use blasx::ffi::{capi, cblas};
use blasx::ffi::{
    CBLAS_COL_MAJOR, CBLAS_LEFT, CBLAS_LOWER, CBLAS_NON_UNIT, CBLAS_NO_TRANS, CBLAS_ROW_MAJOR,
    CBLAS_TRANS, CBLAS_UNIT, CBLAS_UPPER,
};
use blasx::util::prng::Prng;

/// The safe serial reference with the same geometry as the default
/// FFI context (same tile ⇒ same decomposition ⇒ bit-for-bit).
fn serial() -> Context {
    Context::default().with_persistent(false)
}

fn rand(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}


/// Declare a freshly allocated input buffer to the warm process-global
/// context — the C ABI's own invalidation contract: tests in this
/// binary share the default runtime, and the allocator may hand a test
/// the previous test's freed buffer address (outputs are re-epoched
/// automatically; inputs are not).
fn declare<T>(buf: &[T]) {
    unsafe {
        capi::blasx_invalidate_host(
            buf.as_ptr() as *const core::ffi::c_void,
            std::mem::size_of_val(buf),
        )
    }
}

fn transpose(src: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    // col-major rows×cols -> row-major (== col-major cols×rows view)
    let mut out = vec![0.0; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            out[r * cols + c] = src[c * rows + r];
        }
    }
    out
}

/// Max absolute elementwise difference — for the row-major folds that
/// land on a different side/trans code path than the column-major
/// reference (same math, potentially different float summation order;
/// the GEMM fold alone is order-preserving and asserted bit-for-bit).
fn max_diff(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max)
}

#[test]
fn cblas_dgemm_col_major_matches_safe_path() {
    let (m, n, k) = (96usize, 64, 80);
    let mut p = Prng::new(11);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let c0 = rand(&mut p, m * n);
    declare(&a);
    declare(&b);
    let mut c_ffi = c0.clone();
    unsafe { cblas::cblas_dgemm(
        CBLAS_COL_MAJOR, CBLAS_NO_TRANS, CBLAS_TRANS, m as i32, n as i32, k as i32, 1.5,
        a.as_ptr(), m as i32, b.as_ptr(), n as i32, -0.25, c_ffi.as_mut_ptr(), m as i32,
    ) };
    let mut c_safe = c0;
    // transB: B stored n×k
    api::dgemm(&serial(), Trans::No, Trans::Yes, m, n, k, 1.5, &a, m, &b, n, -0.25, &mut c_safe, m)
        .unwrap();
    assert_eq!(c_ffi, c_safe, "cblas_dgemm must be bit-for-bit the safe path");
}

#[test]
fn cblas_dgemm_row_major_matches_transposed_col_major() {
    let (m, n, k) = (48usize, 56, 40);
    let mut p = Prng::new(12);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let c0 = rand(&mut p, m * n);
    declare(&a);
    declare(&b);
    // col-major reference
    let mut c_safe = c0.clone();
    api::dgemm(&serial(), Trans::No, Trans::No, m, n, k, 2.0, &a, m, &b, k, 0.5, &mut c_safe, m)
        .unwrap();
    // the same problem handed over in row-major storage
    let a_rm = transpose(&a, m, k);
    let b_rm = transpose(&b, k, n);
    let mut c_rm = transpose(&c0, m, n);
    declare(&a_rm);
    declare(&b_rm);
    unsafe { cblas::cblas_dgemm(
        CBLAS_ROW_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS, m as i32, n as i32, k as i32, 2.0,
        a_rm.as_ptr(), k as i32, b_rm.as_ptr(), n as i32, 0.5, c_rm.as_mut_ptr(), n as i32,
    ) };
    assert_eq!(transpose(&c_rm, n, m), c_safe, "row-major fold diverged");
}

#[test]
fn cblas_sgemm_works() {
    let n = 64usize;
    let a = vec![1.0f32; n * n];
    let b = vec![2.0f32; n * n];
    let mut c = vec![0.0f32; n * n];
    declare(&a);
    declare(&b);
    unsafe { cblas::cblas_sgemm(
        CBLAS_COL_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS, n as i32, n as i32, n as i32, 1.0,
        a.as_ptr(), n as i32, b.as_ptr(), n as i32, 0.0, c.as_mut_ptr(), n as i32,
    ) };
    assert!(c.iter().all(|&x| x == 2.0 * n as f32));
}

#[test]
fn cblas_triangular_and_symmetric_family_matches_safe_path() {
    let n = 64usize;
    let k = 48usize;
    let mut p = Prng::new(13);
    let a = rand(&mut p, n * n);
    let ak = rand(&mut p, n * k);
    let bk = rand(&mut p, n * k);
    let b = rand(&mut p, n * n);
    let c0 = rand(&mut p, n * n);
    let mut tri = rand(&mut p, n * n);
    for i in 0..n {
        tri[i * n + i] = 2.0;
    }
    declare(&a);
    declare(&ak);
    declare(&bk);
    declare(&b);
    declare(&tri);
    let ni = n as i32;
    let ki = k as i32;

    // syrk (lower, f64)
    let mut c_ffi = c0.clone();
    unsafe { cblas::cblas_dsyrk(
        CBLAS_COL_MAJOR, CBLAS_LOWER, CBLAS_NO_TRANS, ni, ki, 0.7, ak.as_ptr(), ni, 0.3,
        c_ffi.as_mut_ptr(), ni,
    ) };
    let mut c_safe = c0.clone();
    api::syrk(&serial(), Uplo::Lower, Trans::No, n, k, 0.7, &ak, n, 0.3, &mut c_safe, n).unwrap();
    assert_eq!(c_ffi, c_safe, "dsyrk");
    // same logical call handed over in row-major storage
    let ak_rm = transpose(&ak, n, k);
    declare(&ak_rm);
    let mut c_rm = transpose(&c0, n, n);
    unsafe { cblas::cblas_dsyrk(
        CBLAS_ROW_MAJOR, CBLAS_LOWER, CBLAS_NO_TRANS, ni, ki, 0.7, ak_rm.as_ptr(), ki, 0.3,
        c_rm.as_mut_ptr(), ni,
    ) };
    assert!(
        max_diff(&transpose(&c_rm, n, n), &c_safe) < 1e-12,
        "dsyrk row-major fold diverged"
    );

    // syr2k (upper)
    let mut c_ffi = c0.clone();
    unsafe { cblas::cblas_dsyr2k(
        CBLAS_COL_MAJOR, CBLAS_UPPER, CBLAS_NO_TRANS, ni, ki, 1.1, ak.as_ptr(), ni,
        bk.as_ptr(), ni, -0.4, c_ffi.as_mut_ptr(), ni,
    ) };
    let mut c_safe = c0.clone();
    api::syr2k(&serial(), Uplo::Upper, Trans::No, n, k, 1.1, &ak, n, &bk, n, -0.4, &mut c_safe, n)
        .unwrap();
    assert_eq!(c_ffi, c_safe, "dsyr2k");
    let bk_rm = transpose(&bk, n, k);
    declare(&bk_rm);
    let mut c_rm = transpose(&c0, n, n);
    unsafe { cblas::cblas_dsyr2k(
        CBLAS_ROW_MAJOR, CBLAS_UPPER, CBLAS_NO_TRANS, ni, ki, 1.1, ak_rm.as_ptr(), ki,
        bk_rm.as_ptr(), ki, -0.4, c_rm.as_mut_ptr(), ni,
    ) };
    assert!(
        max_diff(&transpose(&c_rm, n, n), &c_safe) < 1e-12,
        "dsyr2k row-major fold diverged"
    );

    // symm (left/upper)
    let mut c_ffi = c0.clone();
    unsafe { cblas::cblas_dsymm(
        CBLAS_COL_MAJOR, CBLAS_LEFT, CBLAS_UPPER, ni, ni, 0.9, a.as_ptr(), ni, b.as_ptr(), ni,
        0.2, c_ffi.as_mut_ptr(), ni,
    ) };
    let mut c_safe = c0.clone();
    api::symm(&serial(), Side::Left, Uplo::Upper, n, n, 0.9, &a, n, &b, n, 0.2, &mut c_safe, n)
        .unwrap();
    assert_eq!(c_ffi, c_safe, "dsymm");
    let a_rm = transpose(&a, n, n);
    let b_row = transpose(&b, n, n);
    declare(&a_rm);
    declare(&b_row);
    let mut c_rm = transpose(&c0, n, n);
    unsafe { cblas::cblas_dsymm(
        CBLAS_ROW_MAJOR, CBLAS_LEFT, CBLAS_UPPER, ni, ni, 0.9, a_rm.as_ptr(), ni,
        b_row.as_ptr(), ni, 0.2, c_rm.as_mut_ptr(), ni,
    ) };
    assert!(
        max_diff(&transpose(&c_rm, n, n), &c_safe) < 1e-12,
        "dsymm row-major fold diverged"
    );

    // trmm (left/upper/unit)
    let mut b_ffi = b.clone();
    unsafe { cblas::cblas_dtrmm(
        CBLAS_COL_MAJOR, CBLAS_LEFT, CBLAS_UPPER, CBLAS_NO_TRANS, CBLAS_UNIT, ni, ni, 1.5,
        tri.as_ptr(), ni, b_ffi.as_mut_ptr(), ni,
    ) };
    let mut b_safe = b.clone();
    api::trmm(&serial(), Side::Left, Uplo::Upper, Trans::No, Diag::Unit, n, n, 1.5, &tri, n, &mut b_safe, n)
        .unwrap();
    assert_eq!(b_ffi, b_safe, "dtrmm");
    let tri_row = transpose(&tri, n, n);
    declare(&tri_row);
    let mut b_io = transpose(&b, n, n);
    unsafe { cblas::cblas_dtrmm(
        CBLAS_ROW_MAJOR, CBLAS_LEFT, CBLAS_UPPER, CBLAS_NO_TRANS, CBLAS_UNIT, ni, ni, 1.5,
        tri_row.as_ptr(), ni, b_io.as_mut_ptr(), ni,
    ) };
    assert!(
        max_diff(&transpose(&b_io, n, n), &b_safe) < 1e-12,
        "dtrmm row-major fold diverged"
    );

    // trsm (left/upper/non-unit), row-major fold included
    let mut b_ffi = b.clone();
    unsafe { cblas::cblas_dtrsm(
        CBLAS_COL_MAJOR, CBLAS_LEFT, CBLAS_UPPER, CBLAS_NO_TRANS, CBLAS_NON_UNIT, ni, ni, 1.0,
        tri.as_ptr(), ni, b_ffi.as_mut_ptr(), ni,
    ) };
    let mut b_safe = b.clone();
    api::trsm(&serial(), Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, &tri, n, &mut b_safe, n)
        .unwrap();
    assert_eq!(b_ffi, b_safe, "dtrsm");

    let tri_rm = transpose(&tri, n, n);
    let mut b_rm = transpose(&b, n, n);
    declare(&tri_rm);
    unsafe { cblas::cblas_dtrsm(
        CBLAS_ROW_MAJOR, CBLAS_LEFT, CBLAS_UPPER, CBLAS_NO_TRANS, CBLAS_NON_UNIT, ni, ni, 1.0,
        tri_rm.as_ptr(), ni, b_rm.as_mut_ptr(), ni,
    ) };
    // Tolerance, not bit-for-bit: the fold runs the Right-side solve,
    // whose substitution/update summation order differs from Left's.
    assert!(
        max_diff(&transpose(&b_rm, n, n), &b_safe) < 1e-9,
        "dtrsm row-major fold diverged"
    );
}

#[test]
fn bad_arguments_are_rejected_without_computing() {
    let n = 8usize;
    let a = vec![1.0f64; n * n];
    let b = vec![1.0f64; n * n];
    let c0 = vec![42.0f64; n * n];

    // bad order enum
    let mut c = c0.clone();
    unsafe { cblas::cblas_dgemm(
        0, CBLAS_NO_TRANS, CBLAS_NO_TRANS, n as i32, n as i32, n as i32, 1.0, a.as_ptr(),
        n as i32, b.as_ptr(), n as i32, 0.0, c.as_mut_ptr(), n as i32,
    ) };
    assert_eq!(c, c0, "bad order must not compute");

    // negative dimension
    let mut c = c0.clone();
    unsafe { cblas::cblas_dgemm(
        CBLAS_COL_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS, -3, n as i32, n as i32, 1.0,
        a.as_ptr(), n as i32, b.as_ptr(), n as i32, 0.0, c.as_mut_ptr(), n as i32,
    ) };
    assert_eq!(c, c0, "negative m must not compute");

    // null input pointer
    let mut c = c0.clone();
    unsafe { cblas::cblas_dgemm(
        CBLAS_COL_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS, n as i32, n as i32, n as i32, 1.0,
        std::ptr::null(), n as i32, b.as_ptr(), n as i32, 0.0, c.as_mut_ptr(), n as i32,
    ) };
    assert_eq!(c, c0, "null A must not compute");

    // the error is retrievable on this thread
    let mut buf = vec![0u8; 256];
    let len = unsafe {
        capi::blasx_last_error(buf.as_mut_ptr() as *mut core::ffi::c_char, buf.len())
    };
    assert!(len > 0, "an error message must have been recorded");
    let msg: String = buf.iter().take_while(|&&c| c != 0).map(|&c| c as char).collect();
    assert!(msg.contains("cblas_dgemm"), "got: {msg}");
    // length-query form (NULL buffer)
    let qlen = unsafe { capi::blasx_last_error(std::ptr::null_mut(), 0) };
    assert_eq!(qlen, len);

    // degenerate sizes are quick returns, not errors
    unsafe { cblas::cblas_dgemm(
        CBLAS_COL_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS, 0, 0, 0, 1.0, std::ptr::null(), 1,
        std::ptr::null(), 1, 0.0, std::ptr::null_mut(), 1,
    ) };
}

#[test]
fn async_jobs_wait_out_of_order() {
    let n = 64usize;
    let jobs = 4;
    let mut p = Prng::new(21);
    let abufs: Vec<Vec<f64>> = (0..jobs).map(|_| rand(&mut p, n * n)).collect();
    let bbufs: Vec<Vec<f64>> = (0..jobs).map(|_| rand(&mut p, n * n)).collect();
    let mut cbufs: Vec<Vec<f64>> = (0..jobs).map(|_| vec![0.0; n * n]).collect();
    for i in 0..jobs {
        declare(&abufs[i]);
        declare(&bbufs[i]);
    }
    let handles: Vec<*mut capi::BlasxJob> = (0..jobs)
        .map(|i| {
            unsafe { capi::blasx_dgemm_async(
                CBLAS_COL_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS, n as i32, n as i32, n as i32,
                1.0, abufs[i].as_ptr(), n as i32, bbufs[i].as_ptr(), n as i32, 0.0,
                cbufs[i].as_mut_ptr(), n as i32,
            ) }
        })
        .collect();
    assert!(handles.iter().all(|h| !h.is_null()));
    for h in handles.into_iter().rev() {
        assert_eq!(unsafe { capi::blasx_wait(h) }, 0);
    }
    for i in 0..jobs {
        let mut want = vec![0.0; n * n];
        api::dgemm(
            &serial(), Trans::No, Trans::No, n, n, n, 1.0, &abufs[i], n, &bbufs[i], n, 0.0,
            &mut want, n,
        )
        .unwrap();
        assert_eq!(cbufs[i], want, "async job {i} diverged from the safe path");
    }
}

#[test]
fn async_aliasing_chain_is_bit_for_bit_serial() {
    let n = 96usize;
    let mut p = Prng::new(22);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let mut tri = rand(&mut p, n * n);
    for i in 0..n {
        tri[i * n + i] = 2.0 + tri[i * n + i].abs();
    }
    let mut c = vec![0.0f64; n * n];
    declare(&a);
    declare(&b);
    declare(&tri);
    let ni = n as i32;
    // C := A·B, then solve tri·X = C in place on the SAME buffer: the
    // admission RAW/WAW edges order the two jobs.
    let j1 = unsafe { capi::blasx_dgemm_async(
        CBLAS_COL_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS, ni, ni, ni, 1.0, a.as_ptr(), ni,
        b.as_ptr(), ni, 0.0, c.as_mut_ptr(), ni,
    ) };
    let j2 = unsafe { capi::blasx_dtrsm_async(
        CBLAS_COL_MAJOR, CBLAS_LEFT, CBLAS_UPPER, CBLAS_NO_TRANS, CBLAS_NON_UNIT, ni, ni, 1.0,
        tri.as_ptr(), ni, c.as_mut_ptr(), ni,
    ) };
    assert!(!j1.is_null() && !j2.is_null());
    assert_eq!(unsafe { capi::blasx_wait(j2) }, 0);
    // j1 retired before j2 could run; done-probe then wait.
    assert_eq!(unsafe { capi::blasx_job_done(j1) }, 1);
    assert_eq!(unsafe { capi::blasx_wait(j1) }, 0);

    let mut want = vec![0.0f64; n * n];
    let s = serial();
    api::dgemm(&s, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want, n).unwrap();
    api::trsm(&s, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, &tri, n, &mut want, n)
        .unwrap();
    assert_eq!(c, want, "C-ABI aliasing chain diverged from serial");
}

#[test]
fn invalidate_host_refreshes_mutated_inputs() {
    let n = 64usize;
    let mut a = vec![1.0f64; n * n];
    let b = vec![1.0f64; n * n];
    let mut c = vec![0.0f64; n * n];
    declare(&a);
    declare(&b);
    let ni = n as i32;
    unsafe { cblas::cblas_dgemm(
        CBLAS_COL_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS, ni, ni, ni, 1.0, a.as_ptr(), ni,
        b.as_ptr(), ni, 0.0, c.as_mut_ptr(), ni,
    ) };
    assert!(c.iter().all(|&x| x == n as f64));
    // mutate the input behind the runtime's back, then declare it
    for x in a.iter_mut() {
        *x = 2.0;
    }
    unsafe {
        capi::blasx_invalidate_host(a.as_ptr() as *const core::ffi::c_void, n * n * 8);
    }
    unsafe { cblas::cblas_dgemm(
        CBLAS_COL_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS, ni, ni, ni, 1.0, a.as_ptr(), ni,
        b.as_ptr(), ni, 0.0, c.as_mut_ptr(), ni,
    ) };
    assert!(
        c.iter().all(|&x| x == 2.0 * n as f64),
        "declared mutation must be visible to the next call"
    );
}

#[test]
fn wait_rejects_null_and_version_is_static() {
    assert_ne!(unsafe { capi::blasx_wait(std::ptr::null_mut()) }, 0);
    assert_eq!(unsafe { capi::blasx_job_done(std::ptr::null()) }, -1);
    let v = capi::blasx_version();
    assert!(!v.is_null());
    let s = unsafe { std::ffi::CStr::from_ptr(v) }.to_str().unwrap();
    assert!(s.starts_with("blasx "), "got {s}");
}
