//! Cross-call properties of the persistent device runtime: warm calls
//! must be bit-for-bit identical to a fresh engine, measurably cheaper
//! (cache hits instead of host transfers), and coherent under host
//! mutation, in-place chains, tile-size switches, and concurrent
//! callers.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::{self, Context, GemmBatchEntry};
use blasx::hostblas;
use blasx::util::prng::Prng;

fn warm_ctx() -> Context {
    Context::new(2).with_arena(8 << 20).with_tile(32)
}

fn rand(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

fn max_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// The tentpole acceptance property: a second identical dgemm through a
/// warm context performs ZERO host→device tile transfers for unchanged
/// operands, serving everything from the resident tile caches.
#[test]
fn warm_second_call_does_zero_host_transfers() {
    let ctx = warm_ctx();
    let (m, n, k) = (96, 80, 64);
    let mut p = Prng::new(71);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let mut c = vec![0.0; m * n];

    // beta = 0 ⇒ tasks never read C, so a fully warm call moves nothing.
    let rep1 = api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
        .unwrap();
    assert!(rep1.transfers.input_host_reads() > 0, "cold call must fetch tiles: {rep1:?}");
    let c1 = c.clone();

    let rep2 = api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
        .unwrap();
    assert_eq!(
        rep2.transfers.total_host_reads(),
        0,
        "warm call must be transfer-free: {:?}",
        rep2.transfers
    );
    assert!(
        rep2.transfers.l1_hits + rep2.transfers.peer_copies > 0,
        "warm call must be served from the tile caches: {:?}",
        rep2.transfers
    );

    // …and bit-for-bit identical, both across calls and vs the oracle.
    assert_eq!(c, c1, "warm call numerics must match the cold call exactly");
    let mut want = vec![0.0; m * n];
    hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut want, m);
    assert!(max_diff(&c, &want) < 1e-10);
}

/// Repeated mixed-routine calls through one warm context agree
/// BIT-FOR-BIT with a fresh one-shot engine per call: cache hits change
/// where tile bytes come from, never what the kernels compute.
#[test]
fn warm_calls_bit_identical_to_fresh_engine() {
    let warm = warm_ctx();
    let mut p = Prng::new(72);
    for round in 0..3 {
        let (m, n, k) = (64 + 16 * round, 80, 48 + round);
        let a = rand(&mut p, m * k);
        let b = rand(&mut p, k * n);
        let c0 = rand(&mut p, m * n);
        // The round's input buffers are fresh allocations with new
        // contents — declare them per the warm runtime's liveness
        // contract (the allocator may reuse a previous round's
        // addresses).
        warm.invalidate_host(&a);
        warm.invalidate_host(&b);

        let mut c_warm = c0.clone();
        api::dgemm(&warm, Trans::No, Trans::No, m, n, k, 1.1, &a, m, &b, k, -0.3, &mut c_warm, m)
            .unwrap();

        let fresh = warm_ctx().with_persistent(false);
        let mut c_fresh = c0.clone();
        api::dgemm(&fresh, Trans::No, Trans::No, m, n, k, 1.1, &a, m, &b, k, -0.3, &mut c_fresh, m)
            .unwrap();
        assert_eq!(c_warm, c_fresh, "round {round}: warm vs fresh dgemm");

        // a symmetric routine through the same warm engine
        let nn = 64;
        let sa = rand(&mut p, nn * k.max(1));
        let sc0 = rand(&mut p, nn * nn);
        warm.invalidate_host(&sa);
        let mut sc_warm = sc0.clone();
        api::syrk(&warm, Uplo::Lower, Trans::No, nn, k, 0.7, &sa, nn, 0.4, &mut sc_warm, nn)
            .unwrap();
        let mut sc_fresh = sc0.clone();
        api::syrk(&fresh, Uplo::Lower, Trans::No, nn, k, 0.7, &sa, nn, 0.4, &mut sc_fresh, nn)
            .unwrap();
        assert_eq!(sc_warm, sc_fresh, "round {round}: warm vs fresh syrk");
    }
    assert!(warm.runtime_calls() >= 6, "all calls went through the resident runtime");
}

/// Mutating an input between calls + `invalidate_host` refreshes
/// exactly the mutated operand's tiles; untouched operands stay warm.
#[test]
fn mutated_input_invalidation_refreshes_stale_tiles() {
    let ctx = warm_ctx();
    let (m, n, k) = (96, 64, 64);
    let mut p = Prng::new(73);
    let mut a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let mut c = vec![0.0; m * n];
    api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m).unwrap();

    // Rewrite A in place, declare it, and verify the runtime re-reads
    // it (and only it) while computing the correct new product.
    p.fill_f64(&mut a, -2.0, 2.0);
    ctx.invalidate_host(&a);
    let rep = api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
        .unwrap();
    assert!(rep.transfers.host_reads[0] > 0, "mutated A must be re-fetched: {:?}", rep.transfers);
    assert_eq!(rep.transfers.host_reads[1], 0, "untouched B stays warm: {:?}", rep.transfers);

    let mut want = vec![0.0; m * n];
    hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut want, m);
    assert!(max_diff(&c, &want) < 1e-10, "stale tiles served after invalidation");
}

/// Output buffers need no declaration: every call epoch-bumps its C
/// range, so reading the rewritten buffer in a later call (TRMM twice
/// in place) can never hit stale tiles.
#[test]
fn inplace_outputs_stay_coherent_across_calls() {
    let ctx = warm_ctx();
    let n = 64;
    let mut p = Prng::new(74);
    // well-conditioned triangle (same recipe as tests/real_engine.rs)
    let mut a = rand(&mut p, n * n);
    for x in a.iter_mut() {
        *x *= 0.5 / (n as f64).sqrt();
    }
    for i in 0..n {
        a[i * n + i] = 2.0;
    }
    let mut b = rand(&mut p, n * n);
    let mut want = b.clone();

    for _ in 0..2 {
        api::trmm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, &a, n, &mut b, n)
            .unwrap();
        hostblas::trmm_ref(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, &a, n, &mut want, n);
    }
    assert!(max_diff(&b, &want) < 1e-8, "{}", max_diff(&b, &want));

    // …and the round-trip identity through the same warm engine.
    let orig = b.clone();
    api::trmm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 2.0, &a, n, &mut b, n)
        .unwrap();
    api::trsm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 0.5, &a, n, &mut b, n)
        .unwrap();
    assert!(max_diff(&b, &orig) < 1e-8);
}

/// Two batch problems sharing one base pointer with different leading
/// dimensions must not alias each other's cached tiles (the `ld`
/// TileKey discriminant — ROADMAP open item from PR 2 review).
#[test]
fn batch_problems_sharing_base_pointer_with_different_ld() {
    let ctx = warm_ctx();
    let (m, n, k) = (40, 24, 64);
    let (lda0, lda1) = (40, 41);
    let mut p = Prng::new(75);
    // one buffer, two strided views — big enough for the wider view
    let a = rand(&mut p, lda1 * k);
    let b0 = rand(&mut p, k * n);
    let b1 = rand(&mut p, k * n);
    let mut c0 = vec![0.0; m * n];
    let mut c1 = vec![0.0; m * n];

    let mut e0 = GemmBatchEntry::new(m, n, k, 1.0, 0.0);
    e0.lda = lda0;
    let mut e1 = GemmBatchEntry::new(m, n, k, 1.0, 0.0);
    e1.lda = lda1;

    {
        let mut crefs: Vec<&mut [f64]> = vec![c0.as_mut_slice(), c1.as_mut_slice()];
        api::dgemm_batched(&ctx, &[e0, e1], &[&a, &a], &[&b0, &b1], &mut crefs).unwrap();
    }

    for (lda, bb, cc) in [(lda0, &b0, &c0), (lda1, &b1, &c1)] {
        let mut want = vec![0.0; m * n];
        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, lda, bb, k, 0.0, &mut want, m);
        assert!(
            max_diff(cc, &want) < 1e-10,
            "lda={lda}: aliased tile cache entries ({})",
            max_diff(cc, &want)
        );
    }
}

/// A fused batch repeated through the warm runtime reuses its tiles
/// like single calls do.
#[test]
fn warm_batch_reuses_tiles() {
    let ctx = warm_ctx();
    let shapes = [(40usize, 24usize, 33usize), (65, 17, 9), (48, 48, 48)];
    let entries: Vec<GemmBatchEntry> =
        shapes.iter().map(|&(m, n, k)| GemmBatchEntry::new(m, n, k, 1.0, 0.0)).collect();
    let mut p = Prng::new(76);
    let abufs: Vec<Vec<f64>> = shapes.iter().map(|&(m, _, k)| rand(&mut p, m * k)).collect();
    let bbufs: Vec<Vec<f64>> = shapes.iter().map(|&(_, n, k)| rand(&mut p, k * n)).collect();
    let mut cbufs: Vec<Vec<f64>> = shapes.iter().map(|&(m, n, _)| vec![0.0; m * n]).collect();
    let arefs: Vec<&[f64]> = abufs.iter().map(Vec::as_slice).collect();
    let brefs: Vec<&[f64]> = bbufs.iter().map(Vec::as_slice).collect();

    let rep1 = {
        let mut crefs: Vec<&mut [f64]> = cbufs.iter_mut().map(Vec::as_mut_slice).collect();
        api::dgemm_batched(&ctx, &entries, &arefs, &brefs, &mut crefs).unwrap()
    };
    assert!(rep1.transfers.input_host_reads() > 0);
    let first: Vec<Vec<f64>> = cbufs.clone();

    let rep2 = {
        let mut crefs: Vec<&mut [f64]> = cbufs.iter_mut().map(Vec::as_mut_slice).collect();
        api::dgemm_batched(&ctx, &entries, &arefs, &brefs, &mut crefs).unwrap()
    };
    assert_eq!(rep2.transfers.total_host_reads(), 0, "{:?}", rep2.transfers);
    assert_eq!(cbufs, first, "warm batch must be bit-identical");
}

/// Cross-role tile reuse (ROADMAP item closed by the serve PR): a
/// buffer warmed as the A operand hits when later passed as B — the
/// operand role is no longer part of `TileKey` equality.
#[test]
fn cross_role_warm_hit_a_then_b() {
    let ctx = warm_ctx();
    // n = 80 with t = 32 leaves 16-wide edge tiles, exercising the
    // padding re-assertion on cross-role hits.
    let n = 80;
    let mut p = Prng::new(80);
    let x = rand(&mut p, n * n); // the shared operand
    let b0 = rand(&mut p, n * n);
    let a2 = rand(&mut p, n * n);
    let mut c = vec![0.0; n * n];

    // call 1: X rides as A (warms X's tiles under the A role)
    let rep1 =
        api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &x, n, &b0, n, 0.0, &mut c, n)
            .unwrap();
    assert!(rep1.transfers.host_reads[0] > 0);

    // call 2: X rides as B — every tile must come from the warm cache
    let rep2 =
        api::dgemm(&ctx, Trans::No, Trans::No, n, n, n, 1.0, &a2, n, &x, n, 0.0, &mut c, n)
            .unwrap();
    assert_eq!(
        rep2.transfers.host_reads[1],
        0,
        "X was warmed as A and must hit as B: {:?}",
        rep2.transfers
    );
    assert!(rep2.transfers.host_reads[0] > 0, "a2 is cold");

    // …and the numerics match the serial engine exactly.
    let fresh = warm_ctx().with_persistent(false);
    let mut want = vec![0.0; n * n];
    api::dgemm(&fresh, Trans::No, Trans::No, n, n, n, 1.0, &a2, n, &x, n, 0.0, &mut want, n)
        .unwrap();
    assert_eq!(c, want, "cross-role reuse changed the numerics");
}

/// Changing the tile size between calls starts a NEW cache generation
/// (`t` is a `TileKey` discriminant) without disturbing the old one:
/// the first call at the new geometry fetches its own tiles, and
/// switching back finds the original generation still warm. The
/// pre-PR-8 runtime instead ran a barrier job and purged every cache
/// here; `tests/dispatch_adaptive.rs` covers the multi-tenant version.
#[test]
fn tile_size_switch_keeps_both_generations_warm() {
    let mut ctx = warm_ctx();
    let (m, n, k) = (96, 96, 96);
    let mut p = Prng::new(77);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let mut c = vec![0.0; m * n];
    let mut want = vec![0.0; m * n];
    hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut want, m);

    api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m).unwrap();
    assert!(max_diff(&c, &want) < 1e-10);

    ctx.cfg.t = 48; // same runtime, new block geometry
    let rep = api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
        .unwrap();
    assert!(
        rep.transfers.input_host_reads() > 0,
        "a new geometry's generation starts cold: {:?}",
        rep.transfers
    );
    assert!(max_diff(&c, &want) < 1e-10);

    // Warm repeat at the new geometry...
    let rep = api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
        .unwrap();
    assert_eq!(rep.transfers.input_host_reads(), 0, "t=48 generation: {:?}", rep.transfers);

    // ...and the ORIGINAL generation survived the switch: no purge,
    // no refetch when the tile size goes back.
    ctx.cfg.t = 32;
    let rep = api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
        .unwrap();
    assert_eq!(
        rep.transfers.input_host_reads(),
        0,
        "switching back must find the old generation warm: {:?}",
        rep.transfers
    );
    assert!(max_diff(&c, &want) < 1e-10);
}

/// Concurrent callers sharing one Context are admitted as concurrent
/// jobs (disjoint buffers ⇒ no dependency edges) and interleave on the
/// resident workers; every call stays correct. The deeper concurrency
/// guarantees live in `tests/serve_concurrent.rs`.
#[test]
fn concurrent_callers_share_one_runtime() {
    let ctx = warm_ctx();
    let (m, n, k) = (64, 64, 48);
    std::thread::scope(|scope| {
        for seed in 0..3u64 {
            let ctx = ctx.clone();
            scope.spawn(move || {
                let mut p = Prng::new(100 + seed);
                for _ in 0..3 {
                    let a = rand(&mut p, m * k);
                    let b = rand(&mut p, k * n);
                    let mut c = vec![0.0; m * n];
                    // fresh input allocations each iteration: declare
                    // them (concurrent invalidations are part of what
                    // this test exercises)
                    ctx.invalidate_host(&a);
                    ctx.invalidate_host(&b);
                    api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
                        .unwrap();
                    let mut want = vec![0.0; m * n];
                    hostblas::gemm_blocked(
                        Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut want, m,
                    );
                    assert!(max_diff(&c, &want) < 1e-10);
                }
            });
        }
    });
    assert_eq!(ctx.runtime_calls(), 9);
}

/// Eviction pressure across calls: a small arena keeps the warm path
/// correct even when the previous call's tiles were partially evicted.
#[test]
fn warm_calls_correct_under_cache_pressure() {
    // 9 tiles/device: constant eviction, cross-call hits are partial.
    let ctx = Context::new(2).with_arena(9 * 32 * 32 * 8).with_tile(32);
    let (m, n, k) = (160, 160, 160);
    let mut p = Prng::new(78);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let mut want = vec![0.0; m * n];
    hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut want, m);
    for call in 0..3 {
        let mut c = vec![0.0; m * n];
        api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
            .unwrap();
        assert!(max_diff(&c, &want) < 1e-10, "call {call}");
    }
}

/// f32 and f64 jobs share one resident engine (byte-granular arenas).
#[test]
fn mixed_dtypes_share_the_runtime() {
    let ctx = warm_ctx();
    let (m, n, k) = (64, 48, 40);
    let mut p = Prng::new(79);
    let ad = rand(&mut p, m * k);
    let bd = rand(&mut p, k * n);
    let mut cd = vec![0.0f64; m * n];
    api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &ad, m, &bd, k, 0.0, &mut cd, m).unwrap();

    let mut af = vec![0.0f32; m * k];
    let mut bf = vec![0.0f32; k * n];
    p.fill_f32(&mut af, -1.0, 1.0);
    p.fill_f32(&mut bf, -1.0, 1.0);
    let mut cf = vec![0.0f32; m * n];
    api::sgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &af, m, &bf, k, 0.0, &mut cf, m).unwrap();

    let mut wantf = vec![0.0f32; m * n];
    hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0f32, &af, m, &bf, k, 0.0, &mut wantf, m);
    let df = cf.iter().zip(&wantf).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(df < 1e-3, "{df}");
    assert_eq!(ctx.runtime_calls(), 2);
}
