//! Cross-language contract: every tile-op variant the Rust taskizers can
//! emit must exist in the AOT artifact set (name, signature and file),
//! for every dtype/tile the manifest advertises. This is the seam
//! between `TileOp::kernel_name()` (Rust) and `model.REGISTRY` (Python)
//! — a silent rename on either side fails here, not at 2am in a run.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::Dtype;
use blasx::runtime::{ArgSlot, ArtifactStore};
use blasx::task::TileOp;

fn all_tile_ops() -> Vec<TileOp> {
    let mut ops = Vec::new();
    for ta in [Trans::No, Trans::Yes] {
        for tb in [Trans::No, Trans::Yes] {
            ops.push(TileOp::Gemm { ta, tb });
        }
    }
    for uplo in [Uplo::Upper, Uplo::Lower] {
        for trans in [Trans::No, Trans::Yes] {
            ops.push(TileOp::SyrkDiag { uplo, trans });
            ops.push(TileOp::Syr2kDiag { uplo, trans });
        }
    }
    for side in [Side::Left, Side::Right] {
        for uplo in [Uplo::Upper, Uplo::Lower] {
            for ta in [Trans::No, Trans::Yes] {
                for diag in [Diag::NonUnit, Diag::Unit] {
                    ops.push(TileOp::TrmmDiag { side, uplo, ta, diag });
                    ops.push(TileOp::TrsmDiag { side, uplo, ta, diag });
                }
            }
            ops.push(TileOp::SymmDiag { side, uplo });
        }
    }
    ops.push(TileOp::Scal);
    ops
}

#[test]
fn every_tile_op_has_an_artifact() {
    let store = ArtifactStore::open_default().expect("run `make artifacts`");
    let ops = all_tile_ops();
    assert_eq!(ops.len(), 49, "variant inventory drifted");
    for op in &ops {
        let name = op.kernel_name();
        let sig = store
            .signature(&name)
            .unwrap_or_else(|e| panic!("{name}: missing from manifest: {e}"));
        // tile slots must precede scalars, C is always present
        assert!(sig.contains(&ArgSlot::TileC), "{name}: no C slot");
        for (dtype, t) in
            [(Dtype::F64, 64), (Dtype::F64, 256), (Dtype::F32, 64), (Dtype::F32, 256)]
        {
            assert!(
                store.available(&name, dtype, t),
                "{name}: artifact file missing for {dtype:?} T={t}"
            );
        }
    }
}

#[test]
fn signatures_match_op_arity() {
    let store = ArtifactStore::open_default().expect("run `make artifacts`");
    for op in all_tile_ops() {
        let name = op.kernel_name();
        let sig = store.signature(&name).unwrap();
        let has_a = sig.contains(&ArgSlot::TileA);
        let has_b = sig.contains(&ArgSlot::TileB);
        match op {
            TileOp::Gemm { .. } | TileOp::Syr2kDiag { .. } | TileOp::SymmDiag { .. } => {
                assert!(has_a && has_b, "{name}: needs a and b");
            }
            TileOp::SyrkDiag { .. } | TileOp::TrmmDiag { .. } | TileOp::TrsmDiag { .. } => {
                assert!(has_a && !has_b, "{name}: needs a only");
            }
            TileOp::Scal => assert!(!has_a && !has_b, "{name}: takes only c"),
        }
        // alpha present except for scal; beta absent for trmm/trsm/scal
        let has_alpha = sig.contains(&ArgSlot::Alpha);
        let has_beta = sig.contains(&ArgSlot::Beta);
        match op {
            TileOp::Scal => assert!(!has_alpha && has_beta, "{name}"),
            TileOp::TrmmDiag { .. } | TileOp::TrsmDiag { .. } => {
                assert!(has_alpha && !has_beta, "{name}")
            }
            _ => assert!(has_alpha && has_beta, "{name}"),
        }
    }
}
