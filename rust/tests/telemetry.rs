//! Telemetry-plane and flight-recorder contract tests: the sampler's
//! zero-cost-when-off guarantee (counting allocator), gauge
//! monotonicity under multi-client load, bounded flight-ring memory,
//! incident auto-dump on a seeded device kill, the
//! `/healthz`-vs-metrics single-source-of-truth regression, and an
//! end-to-end scrape of the stdlib HTTP endpoint.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::{self, Context};
use blasx::fault::FaultPlan;
use blasx::trace::prometheus;
use blasx::trace::{FlightRecorder, TelemetryServer, FLIGHT_RING};
use blasx::util::json::{self, Json};
use blasx::util::prng::Prng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::time::Duration;

// ---- counting allocator (thread-local, drop-free TLS) --------------

thread_local! {
    // Cell<u64> has no destructor, so the TLS slot is never torn down
    // and counting from inside the allocator can never re-enter a
    // destroyed key.
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the only addition is
// a thread-local counter bump, which does not allocate.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---- helpers -------------------------------------------------------

const DEVICES: usize = 2;

fn rand(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

fn upper_tri(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut a = rand(p, n * n);
    for x in a.iter_mut() {
        *x *= 0.5 / (n as f64).sqrt();
    }
    for i in 0..n {
        a[i * n + i] = 2.0;
    }
    a
}

/// One client's chain: dgemm then an in-place dtrsm on its output,
/// twice — enough tile traffic on both devices to trip any `op`-indexed
/// fault trigger and populate every gauge family.
fn chain_workload(ctx: &Context, seed: u64) {
    let (m, n, k) = (96, 64, 48);
    let mut p = Prng::new(seed);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let tri = upper_tri(&mut p, m);
    let mut c = vec![0.0; m * n];
    for _ in 0..2 {
        api::dgemm(ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
            .expect("dgemm");
        api::trsm(
            ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0, &tri, m, &mut c, m,
        )
        .expect("trsm");
    }
}

/// A context whose fault plan kills device 1 mid-run, driven by a
/// 4-client load so the kill reliably fires. Returns after the load
/// completed (recovery makes the jobs succeed regardless).
fn killed_ctx() -> Context {
    let plan = FaultPlan::parse("kill@dev1:op12").expect("plan parses");
    let ctx = Context::new(DEVICES).with_arena(8 << 20).with_tile(32).with_fault_plan(Some(plan));
    std::thread::scope(|scope| {
        for seed in 0..4u64 {
            let ctx = ctx.clone();
            scope.spawn(move || chain_workload(&ctx, 7100 + seed));
        }
    });
    ctx
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("blasx_telem_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Minimal HTTP/1.0 GET; returns (status line, body).
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    let mut stream = std::net::TcpStream::connect(addr).expect("connect scrape endpoint");
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(stream, "GET {path} HTTP/1.0\r\nHost: blasx\r\n\r\n").expect("send request");
    let mut text = String::new();
    stream.read_to_string(&mut text).expect("read response");
    let status = text.lines().next().unwrap_or("").to_string();
    let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn metric(parsed: &[(String, Vec<(String, String)>, f64)], name: &str, label: Option<(&str, &str)>) -> Option<f64> {
    parsed
        .iter()
        .find(|(n, ls, _)| {
            n == name && label.map_or(true, |(k, v)| ls.iter().any(|(lk, lv)| lk == k && lv == v))
        })
        .map(|e| e.2)
}

// ---- tests ---------------------------------------------------------

/// `BLASX_TELEMETRY_MS` unset (the default) means no sampler thread
/// and no sample ring — warm calls pay nothing for the telemetry
/// plane. A cold context still renders a valid `blasx_up 0` scrape
/// without booting anything.
#[test]
fn sampler_off_by_default_no_thread_no_history() {
    let cold = Context::new(DEVICES).with_tile(64).with_arena(16 << 20);
    let text = cold.render_prometheus();
    let parsed = prometheus::parse(&text);
    assert_eq!(metric(&parsed, "blasx_up", None), Some(0.0), "cold context reports down");
    assert!(!cold.sampler_running());

    // Boot with a real call: still no sampler, still no history.
    chain_workload(&cold, 11);
    assert!(!cold.sampler_running(), "no BLASX_TELEMETRY_MS => no sampler thread");
    assert!(cold.telemetry_history().is_empty(), "no sampler => empty ring");

    // The booted scrape works anyway: gathered fresh at scrape time.
    let parsed = prometheus::parse(&cold.render_prometheus());
    assert_eq!(metric(&parsed, "blasx_up", None), Some(1.0));
    assert!(metric(&parsed, "blasx_jobs_retired_total", None).unwrap_or(0.0) >= 4.0);
}

/// The always-on flight recorder must never allocate on the record
/// path — its rings are preallocated at construction. Measured, not
/// asserted from code reading: the whole binary runs under the
/// counting allocator.
#[test]
fn flight_recording_never_allocates() {
    let fr = FlightRecorder::new(DEVICES);
    let _ = thread_allocs(); // warm the TLS slot outside the window
    let before = thread_allocs();
    for i in 0..10_000u64 {
        fr.record(Some((i % DEVICES as u64) as usize), "retry", i, 1, 0.5);
        fr.record(None, "admit", i, 2, 1.0);
    }
    assert_eq!(thread_allocs(), before, "flight record path allocated");
    assert_eq!(fr.total_events(), 20_000);
    assert!(fr.retained() <= (DEVICES + 1) * FLIGHT_RING, "rings exceeded their bound");
}

/// With the sampler on, the ring fills with time-ordered samples whose
/// counters are monotone and whose rates stay in range, under a
/// 4-client concurrent load.
#[test]
fn sampler_gauges_are_monotone_under_load() {
    let ctx = Context::new(DEVICES)
        .with_tile(64)
        .with_arena(32 << 20)
        .with_telemetry_ms(Some(5));
    std::thread::scope(|scope| {
        for seed in 0..4u64 {
            let ctx = ctx.clone();
            scope.spawn(move || chain_workload(&ctx, 400 + seed));
        }
    });
    assert!(ctx.sampler_running(), "with_telemetry_ms must start the sampler");
    // Let the sampler observe the post-load steady state too.
    std::thread::sleep(Duration::from_millis(40));
    let history = ctx.telemetry_history();
    assert!(history.len() >= 2, "5 ms cadence must have produced samples");

    for w in history.windows(2) {
        assert!(w[1].t_s >= w[0].t_s, "samples must be time-ordered");
        assert!(w[1].admitted >= w[0].admitted, "admitted counter regressed");
        assert!(w[1].retired >= w[0].retired, "retired counter regressed");
        for (d0, d1) in w[0].devices.iter().zip(&w[1].devices) {
            assert_eq!(d0.dev, d1.dev);
            assert!(d1.cache_hits >= d0.cache_hits, "cache hits regressed");
            assert!(d1.rounds >= d0.rounds, "worker rounds regressed");
            assert!(d1.arena_high_water >= d0.arena_high_water, "high water regressed");
        }
    }
    let last = history.last().unwrap();
    assert_eq!(last.devices.len(), DEVICES);
    // 4 clients x 2 iterations x (dgemm + trsm) = 16 jobs.
    assert!(last.retired >= 16, "final sample missing retired jobs: {}", last.retired);
    for d in &last.devices {
        assert!((0.0..=1.0).contains(&d.hit_rate), "hit rate out of range");
        assert!((0.0..=1.0).contains(&d.busy_fraction), "busy fraction out of range");
        assert!(d.arena_high_water >= d.arena_in_use);
    }
}

/// A healthy run leaves an admit/retire trail in the flight rings; a
/// manual dump writes a parseable, bounded incident report.
#[test]
fn flight_trail_is_bounded_and_dumpable() {
    let ctx = Context::new(DEVICES).with_tile(64).with_arena(16 << 20);
    chain_workload(&ctx, 55);
    let dir = tmp_dir("manual");
    let path = ctx
        .flight_dump(&dir)
        .expect("booted runtime has a flight recorder")
        .expect("dump writes");
    let report = json::parse(&std::fs::read_to_string(&path).unwrap()).expect("report parses");
    assert_eq!(report.get("schema").and_then(Json::as_str), Some("blasx-incident-v1"));
    assert_eq!(report.get("reason").and_then(Json::as_str), Some("manual"));
    assert_eq!(
        report.get("dead_devices").and_then(Json::as_arr).map(<[Json]>::len),
        Some(0),
        "healthy run must not name dead devices"
    );
    let events = report.get("events").and_then(Json::as_arr).expect("events array");
    assert!(!events.is_empty(), "admissions/retirements must leave a trail");
    assert!(events.len() <= (DEVICES + 1) * FLIGHT_RING, "retained trail exceeds ring bound");
    let counts = report.get("event_counts").expect("event_counts");
    assert!(counts.get("admit").and_then(Json::as_usize).unwrap_or(0) > 0);
    assert!(counts.get("retire").and_then(Json::as_usize).unwrap_or(0) > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance scenario: a seeded kill schedule plus an armed
/// flight directory auto-dumps an incident report that parses and
/// names the dead device — no tracing, no sampler, just the always-on
/// black box.
#[test]
fn kill_schedule_dumps_incident_naming_dead_device() {
    let dir = tmp_dir("kill");
    let plan = FaultPlan::parse("kill@dev1:op12").expect("plan parses");
    let ctx = Context::new(DEVICES).with_arena(8 << 20).with_tile(32).with_fault_plan(Some(plan));
    ctx.set_flight_dir(Some(dir.clone()));
    std::thread::scope(|scope| {
        for seed in 0..4u64 {
            let ctx = ctx.clone();
            scope.spawn(move || chain_workload(&ctx, 9300 + seed));
        }
    });

    let reports: Vec<PathBuf> = std::fs::read_dir(&dir)
        .expect("flight dir exists after the kill")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map_or(false, |n| n.contains("device-kill") && !n.contains("trace"))
        })
        .collect();
    assert!(!reports.is_empty(), "device kill must auto-dump an incident report");

    let report =
        json::parse(&std::fs::read_to_string(&reports[0]).unwrap()).expect("incident parses");
    assert_eq!(report.get("schema").and_then(Json::as_str), Some("blasx-incident-v1"));
    assert_eq!(report.get("reason").and_then(Json::as_str), Some("device-kill"));
    let dead = report.get("dead_devices").and_then(Json::as_arr).expect("dead_devices");
    assert!(
        dead.iter().any(|d| d.as_usize() == Some(1)),
        "incident must name the killed device"
    );
    assert!(
        !report.get("events").and_then(Json::as_arr).unwrap().is_empty(),
        "incident must carry the ring trail"
    );
    // The companion Chrome trace is there and loads.
    let trace_file = reports[0].to_str().unwrap().replace(".json", ".trace.json");
    let trace = json::parse(&std::fs::read_to_string(&trace_file).unwrap()).expect("trace parses");
    assert!(!trace.get("traceEvents").and_then(Json::as_arr).unwrap().is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regression: `/healthz`, `snapshot_metrics()` and the Prometheus
/// rendering must agree on device death, because all three read
/// `EngineCore::dead_devices()` — one source of truth.
#[test]
fn healthz_metrics_and_prometheus_agree_on_death() {
    let ctx = killed_ctx();

    let (healthy, dead) = ctx.health();
    assert!(!healthy, "killed device must fail the health check");
    assert_eq!(dead, vec![1]);

    let m = ctx.snapshot_metrics().expect("metrics snapshot");
    assert_eq!(m.get("fleet_healthy").and_then(Json::as_bool), Some(false));
    let devices = m.get("devices").and_then(Json::as_arr).expect("devices array");
    assert_eq!(devices.len(), DEVICES);
    for d in devices {
        let dev = d.get("dev").and_then(Json::as_usize).unwrap();
        let up = d.get("up").and_then(Json::as_bool).unwrap();
        assert_eq!(up, dev != 1, "device {dev}: snapshot disagrees with the fault ledger");
    }

    let parsed = prometheus::parse(&ctx.render_prometheus());
    assert_eq!(metric(&parsed, "blasx_device_up", Some(("dev", "1"))), Some(0.0));
    assert_eq!(metric(&parsed, "blasx_device_up", Some(("dev", "0"))), Some(1.0));
    assert_eq!(metric(&parsed, "blasx_up", None), Some(1.0), "runtime itself is still up");
}

/// End-to-end scrape: the stdlib HTTP endpoint serves a parseable
/// /metrics body and a /healthz that flips to 503 (naming the device)
/// once the fault plane kills one.
#[test]
fn telemetry_server_round_trip() {
    // Healthy context first.
    let ctx = Context::new(DEVICES).with_tile(64).with_arena(16 << 20);
    chain_workload(&ctx, 77);
    let mut server = TelemetryServer::start("127.0.0.1:0", ctx.clone()).expect("bind");
    let addr = server.addr();

    let (status, body) = http_get(addr, "/metrics");
    assert!(status.contains("200"), "metrics scrape failed: {status}");
    let parsed = prometheus::parse(&body);
    assert_eq!(metric(&parsed, "blasx_up", None), Some(1.0));
    assert!(metric(&parsed, "blasx_arena_bytes_in_use", Some(("dev", "0"))).is_some());
    assert!(metric(&parsed, "blasx_queue_depth", None).is_some());

    let (status, body) = http_get(addr, "/healthz");
    assert!(status.contains("200"), "healthy fleet must 200: {status}");
    assert_eq!(body.trim(), "ok");

    let (status, _) = http_get(addr, "/nope");
    assert!(status.contains("404"));
    server.stop();

    // Now a fleet with a dead device: 503 naming it.
    let ctx = killed_ctx();
    let mut server = TelemetryServer::start("127.0.0.1:0", ctx.clone()).expect("bind");
    let (status, body) = http_get(server.addr(), "/healthz");
    assert!(status.contains("503"), "dead device must 503: {status}");
    assert!(body.contains('1'), "health body must name the dead device: {body}");
    server.stop();
}
