//! Fault tolerance and tenant protection of the resident runtime.
//!
//! The contract under test: a seeded fault schedule (device kill,
//! transient kernel/transfer failures, forced arena OOM) may change
//! *where and when* work executes, but never *what* it computes —
//! recovery re-runs each interrupted task from its host master copies
//! in the same k-order, so results stay bit-for-bit equal to serial
//! execution on a healthy machine. Deadlines, cancellation and
//! admission backpressure abort or refuse individual jobs with
//! distinct error variants while other tenants complete unaffected.
//!
//! Run under both the default harness and `RUST_TEST_THREADS=1`, and
//! in CI additionally with a `BLASX_FAULTS` schedule over the whole
//! suite (the chaos job).

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::{self, Context};
use blasx::error::Error;
use blasx::fault::FaultPlan;
use blasx::util::json::Json;
use blasx::util::prng::Prng;

fn ctx_with_plan(plan: Option<FaultPlan>) -> Context {
    Context::new(2).with_arena(8 << 20).with_tile(32).with_fault_plan(plan)
}

fn serial_ctx() -> Context {
    // The healthy reference: same geometry, one-shot engine, no plan.
    Context::new(2).with_arena(8 << 20).with_tile(32).with_persistent(false)
}

fn rand(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

fn upper_tri(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut a = rand(p, n * n);
    for x in a.iter_mut() {
        *x *= 0.5 / (n as f64).sqrt();
    }
    for i in 0..n {
        a[i * n + i] = 2.0;
    }
    a
}

/// One client's mixed-routine workload (dgemm → dsyrk → in-place
/// dtrsm on the dgemm output, twice). Returns the chain result and
/// the syrk output.
fn client_workload(ctx: &Context, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let (m, n, k) = (96, 64, 48);
    let mut p = Prng::new(seed);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let tri = upper_tri(&mut p, m);
    let sa = rand(&mut p, n * k);
    let mut c = vec![0.0; m * n];
    let mut sc = rand(&mut p, n * n);
    ctx.invalidate_host(&a);
    ctx.invalidate_host(&b);
    ctx.invalidate_host(&tri);
    ctx.invalidate_host(&sa);
    for _ in 0..2 {
        api::dgemm(ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
            .unwrap();
        api::syrk(ctx, Uplo::Lower, Trans::No, n, k, 0.7, &sa, n, 0.4, &mut sc, n).unwrap();
        api::trsm(ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0, &tri, m, &mut c, m)
            .unwrap();
    }
    (c, sc)
}

/// Sum a per-tenant counter across the metrics snapshot.
fn tenant_counter_sum(m: &Json, field: &str) -> usize {
    match m.get("per_tenant") {
        Some(Json::Obj(tenants)) => tenants
            .iter()
            .map(|(_, o)| o.get(field).and_then(Json::as_usize).unwrap_or(0))
            .sum(),
        _ => 0,
    }
}

/// The tentpole acceptance test: a device dies mid-run under a
/// 4-client mixed-routine stress, transient faults hit the survivor —
/// and every client's result is bit-for-bit what the healthy serial
/// engine produces. The trace records the fault; the metrics ledger
/// records the recovery work.
#[test]
fn device_kill_mid_serve_matches_serial_bit_for_bit() {
    let plan = FaultPlan::parse("kill@dev1:op12; kernel@dev0:op3; h2d@dev0:op5x2").unwrap();
    let ctx = ctx_with_plan(Some(plan));
    ctx.set_tracing(true);
    let results: Vec<(u64, Vec<f64>, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4u64)
            .map(|seed| {
                let ctx = ctx.clone();
                scope.spawn(move || {
                    let (c, sc) = client_workload(&ctx, 800 + seed);
                    (seed, c, sc)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(ctx.jobs_in_flight(), 0);
    for (seed, c, sc) in results {
        let (want_c, want_sc) = client_workload(&serial_ctx(), 800 + seed);
        assert_eq!(c, want_c, "client {seed}: chain diverged under device kill");
        assert_eq!(sc, want_sc, "client {seed}: syrk diverged under device kill");
    }
    // The kill left a Fault span in the trace…
    let trace = ctx.chrome_trace_json().expect("tracing was enabled");
    assert!(trace.contains("\"fault\""), "device kill must be visible in the trace");
    // …and the recovery shows up in the per-tenant fault ledger (the
    // transient kernel/h2d specs guarantee at least a retry even if
    // the kill fired while device 1 held no tasks).
    let m = ctx.snapshot_metrics().expect("persistent runtime has metrics");
    let recovery = tenant_counter_sum(&m, "retried")
        + tenant_counter_sum(&m, "degraded")
        + tenant_counter_sum(&m, "migrated");
    assert!(
        recovery > 0,
        "fault schedule fired but no recovery was recorded:\n{}",
        m.to_string_pretty()
    );
}

/// Forced arena-allocation failures degrade to eviction-retry and then
/// the per-task host path — never a panic, never a wrong result.
#[test]
fn injected_oom_degrades_to_host_path_not_panic() {
    // Both a deterministic burst and a seeded probabilistic drizzle.
    for spec in ["oom@dev0:op0x8", "oom@dev0:p0.3; oom@dev1:p0.2; seed=11"] {
        let ctx = ctx_with_plan(Some(FaultPlan::parse(spec).unwrap()));
        let (m, n, k) = (96, 64, 48);
        let mut p = Prng::new(31);
        let a = rand(&mut p, m * k);
        let b = rand(&mut p, k * n);
        let tri = upper_tri(&mut p, m);
        let mut c = vec![0.0; m * n];
        api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
            .unwrap_or_else(|e| panic!("{spec}: OOM must degrade, not fail: {e}"));
        api::trsm(&ctx, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0, &tri, m, &mut c, m)
            .unwrap_or_else(|e| panic!("{spec}: OOM must degrade, not fail: {e}"));
        let mut want = vec![0.0; m * n];
        let serial = serial_ctx();
        api::dgemm(&serial, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut want, m)
            .unwrap();
        api::trsm(&serial, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0, &tri, m, &mut want, m)
            .unwrap();
        assert_eq!(c, want, "{spec}: degraded path diverged from serial");
    }
}

/// A zero deadline reaps the job with `DeadlineExceeded` at the first
/// round boundary, while a concurrent tenant on the same runtime (no
/// deadline) completes normally.
#[test]
fn deadline_reaps_one_tenant_and_spares_the_other() {
    let ctx = ctx_with_plan(None);
    let doomed = ctx.clone().with_deadline_ms(Some(0));
    let n = 64;
    std::thread::scope(|scope| {
        let d = &doomed;
        scope.spawn(move || {
            let mut p = Prng::new(51);
            let a = rand(&mut p, n * n);
            let b = rand(&mut p, n * n);
            let mut c = vec![0.0; n * n];
            let err = api::dgemm(d, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n)
                .expect_err("a 0ms deadline must reap the job");
            assert!(
                matches!(err, Error::DeadlineExceeded { limit_ms: 0 }),
                "wrong error for an expired deadline: {err}"
            );
        });
        let healthy = &ctx;
        scope.spawn(move || {
            let mut p = Prng::new(52);
            let a = rand(&mut p, n * n);
            let b = rand(&mut p, n * n);
            let mut c = vec![0.0; n * n];
            api::dgemm(healthy, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut c, n)
                .expect("the deadline-free tenant must be unaffected");
            let mut want = vec![0.0; n * n];
            api::dgemm(&serial_ctx(), Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want, n)
                .unwrap();
            assert_eq!(c, want);
        });
    });
    assert_eq!(ctx.jobs_in_flight(), 0);
}

/// Cancelling the dep-blocked second job of an aliasing chain aborts
/// it with `Cancelled` — deterministically, because the reap runs
/// before the scheduler can ever pick the job — and leaves the first
/// job's output intact.
#[test]
fn cancel_aborts_a_chained_job_and_keeps_the_predecessor_result() {
    let ctx = ctx_with_plan(None);
    // Big enough that the dgemm cannot retire (and unblock the trsm)
    // in the microseconds before the cancel request lands.
    let n = 256;
    let mut p = Prng::new(61);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let tri = upper_tri(&mut p, n);
    let mut c = vec![0.0; n * n];
    ctx.scope(|s| {
        let (ra, rb, rt) = (s.input(&a), s.input(&b), s.input(&tri));
        let rc = s.buffer(&mut c);
        let h1 = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rc, n)?;
        // The trsm reads AND overwrites the dgemm's output, so it is
        // dep-blocked behind h1 — cancelled before it can ever run.
        let h2 = s.dtrsm(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, rt, n, rc, n)?;
        h2.cancel();
        h2.cancel(); // idempotent
        let err = h2.wait().expect_err("a cancelled dep-blocked job must not run");
        assert!(matches!(err, Error::Cancelled), "wrong error for cancel: {err}");
        h1.wait().expect("the predecessor must be unaffected");
        Ok(())
    })
    .unwrap();
    // c holds exactly the dgemm result: the cancelled trsm never
    // touched it.
    let mut want = vec![0.0; n * n];
    api::dgemm(&serial_ctx(), Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want, n)
        .unwrap();
    assert_eq!(c, want, "cancelled successor must leave the chain at the predecessor's output");
}

/// At `admit_capacity` (or a tenant's quota) further submissions fail
/// fast with `Backpressure` — nothing is enqueued, the rejection is
/// counted, and the runtime keeps serving afterwards.
#[test]
fn backpressure_rejects_at_capacity_and_recovers() {
    let ctx = ctx_with_plan(None).with_admit_capacity(1);
    let n = 192;
    let mut p = Prng::new(71);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let mut c1 = vec![0.0; n * n];
    let mut c2 = vec![0.0; n * n];
    ctx.scope(|s| {
        let (ra, rb) = (s.input(&a), s.input(&b));
        let rc1 = s.buffer(&mut c1);
        let rc2 = s.buffer(&mut c2);
        let h1 = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rc1, n)?;
        let err = s
            .dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rc2, n)
            .map(|h| h.detach())
            .expect_err("the queue is at capacity: the second job must be refused");
        assert!(matches!(err, Error::Backpressure(_)), "wrong error at capacity: {err}");
        h1.wait()?;
        // Capacity freed: the runtime serves again.
        let h3 = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rc2, n)?;
        h3.wait()?;
        Ok(())
    })
    .unwrap();
    assert_eq!(c1, c2, "identical inputs must give identical outputs after backpressure");
    let m = ctx.snapshot_metrics().expect("persistent runtime has metrics");
    assert!(
        m.get("jobs_rejected").and_then(Json::as_usize).unwrap_or(0) >= 1,
        "the rejection must be counted:\n{}",
        m.to_string_pretty()
    );
    assert!(tenant_counter_sum(&m, "rejected") >= 1);

    // The per-tenant quota takes the same fail-fast path.
    let ctx = ctx_with_plan(None).with_tenant_quota(1);
    let mut q1 = vec![0.0; n * n];
    let mut q2 = vec![0.0; n * n];
    ctx.scope(|s| {
        let (ra, rb) = (s.input(&a), s.input(&b));
        let rq1 = s.buffer(&mut q1);
        let rq2 = s.buffer(&mut q2);
        let h1 = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rq1, n)?;
        let err = s
            .dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rq2, n)
            .map(|h| h.detach())
            .expect_err("this tenant is at quota: the second job must be refused");
        assert!(matches!(err, Error::Backpressure(_)), "wrong error at quota: {err}");
        h1.wait()?;
        Ok(())
    })
    .unwrap();
}

/// Regression for the tentpole's surgical-invalidation claim: a failed
/// job must NOT purge the shared tile caches. A warm tenant stays warm
/// (zero host reads) across another tenant's deadline failure.
#[test]
fn failed_job_does_not_purge_warm_caches() {
    let ctx = ctx_with_plan(None);
    let (m, n, k) = (96, 64, 48);
    let mut p = Prng::new(81);
    let a = rand(&mut p, m * k);
    let b = rand(&mut p, k * n);
    let mut c = vec![0.0; m * n];
    // Warm up: the second call must already be transfer-free (beta = 0,
    // so C is never host-read).
    api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m).unwrap();
    let warm =
        api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
            .unwrap();
    assert_eq!(warm.transfers.input_host_reads(), 0, "call 2 must run fully warm");

    // Another tenant fails on the same runtime (disjoint buffers).
    let doomed = ctx.clone().with_deadline_ms(Some(0));
    let mut p2 = Prng::new(82);
    let da = rand(&mut p2, m * k);
    let db = rand(&mut p2, k * n);
    let mut dc = vec![0.0; m * n];
    let err = api::dgemm(&doomed, Trans::No, Trans::No, m, n, k, 1.0, &da, m, &db, k, 0.0, &mut dc, m)
        .expect_err("the doomed tenant must be reaped");
    assert!(matches!(err, Error::DeadlineExceeded { .. }));

    // The warm tenant is still warm: the failure was retired without a
    // global purge.
    let after =
        api::dgemm(&ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, m)
            .unwrap();
    assert_eq!(
        after.transfers.input_host_reads(),
        0,
        "a failed job must not purge other tenants' warm tiles"
    );
}
