//! Observability-layer contract tests: span recording under
//! multi-client load, histogram percentiles against a brute-force
//! oracle, Chrome trace-event export validity, and the
//! zero-allocation guarantee of the disabled recorder.
//!
//! The whole test binary runs under a counting global allocator
//! (thread-local counters, so concurrent tests don't interfere) —
//! that is what makes the disabled-recorder check a real measurement
//! rather than a code-reading exercise.

use blasx::api::types::Trans;
use blasx::api::{self, Context};
use blasx::trace::{all_profiles, comm_volumes, Histogram, Recorder, SpanKind};
use blasx::util::json::{self, Json};
use blasx::util::prng::Prng;
use blasx::util::stats::percentile_sorted;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

// ---- counting allocator (thread-local, drop-free TLS) --------------

thread_local! {
    // Cell<u64> has no destructor, so the TLS slot is never torn down
    // and counting from inside the allocator can never re-enter a
    // destroyed key.
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the only addition is
// a thread-local counter bump, which does not allocate.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---- helpers -------------------------------------------------------

const N: usize = 192;
const T: usize = 64;
const DEVICES: usize = 2;
const CLIENTS: usize = 4;
const JOBS_PER_CLIENT: usize = 2;

/// Run a 4-client DGEMM load over one traced persistent context and
/// return it (trace + metrics retained inside).
fn traced_load() -> Context {
    let ctx = Context::new(DEVICES).with_tile(T).with_arena(32 << 20);
    ctx.set_tracing(true);
    std::thread::scope(|scope| {
        for client in 0..CLIENTS {
            let ctx = ctx.clone();
            scope.spawn(move || {
                let mut p = Prng::new(900 + client as u64);
                let mut a = vec![0.0f64; N * N];
                let mut b = vec![0.0f64; N * N];
                let mut c = vec![0.0f64; N * N];
                p.fill_f64(&mut a, -1.0, 1.0);
                p.fill_f64(&mut b, -1.0, 1.0);
                for _ in 0..JOBS_PER_CLIENT {
                    api::dgemm(
                        &ctx, Trans::No, Trans::No, N, N, N, 1.0, &a, N, &b, N, 0.0, &mut c, N,
                    )
                    .expect("traced dgemm");
                }
            });
        }
    });
    ctx
}

/// All "X" (complete) events of a parsed Chrome trace document.
fn complete_events(doc: &Json) -> Vec<&Json> {
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array")
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .collect()
}

fn num(e: &Json, key: &str) -> f64 {
    e.get(key).and_then(Json::as_f64).unwrap_or_else(|| panic!("event field {key}"))
}

// ---- tests ---------------------------------------------------------

/// Under concurrent multi-client load the recorder must yield (a) a
/// profileable trace with compute and H2D time on the devices, (b)
/// kernel spans nested inside scheduler-round spans on their worker's
/// track, and (c) per-job queued→running lifecycles in admission
/// order per track, labelled with tenant and routine.
#[test]
fn spans_nest_and_order_under_concurrent_load() {
    let ctx = traced_load();

    // (a) The sim-era analyses run unchanged on the real spans.
    let trace = ctx.snapshot_trace().expect("trace snapshot");
    let profiles = all_profiles(&trace);
    assert_eq!(profiles.len(), DEVICES);
    let compt: f64 = profiles.iter().map(|p| p.compt).sum();
    assert!(compt > 0.0, "no compute time recorded");
    let hd: f64 = comm_volumes(&trace).iter().map(|v| v.hd_bytes).sum();
    assert!(hd > 0.0, "cold first calls must move host tiles");

    let doc = json::parse(&ctx.chrome_trace_json().expect("chrome json")).expect("valid json");
    let xs = complete_events(&doc);
    assert!(!xs.is_empty());

    // (b) Kernel-in-round nesting per device track (pid 0). Rounds on
    // one track come from one worker thread, so containment is exact.
    let eps = 1.0; // µs slack for f64 rounding in ts/dur
    let mut kernels = 0;
    for e in xs.iter().filter(|e| num(e, "pid") == 0.0) {
        if e.get("name").and_then(Json::as_str) != Some("kernel") {
            continue;
        }
        kernels += 1;
        let (tid, ts, dur) = (num(e, "tid"), num(e, "ts"), num(e, "dur"));
        assert!(tid < DEVICES as f64, "kernel on unknown device track");
        let contained = xs.iter().any(|r| {
            r.get("name").and_then(Json::as_str) == Some("round")
                && num(r, "pid") == 0.0
                && num(r, "tid") == tid
                && num(r, "ts") <= ts + eps
                && ts + dur <= num(r, "ts") + num(r, "dur") + eps
        });
        assert!(contained, "kernel span outside every round span on its track");
    }
    assert!(kernels > 0, "no kernel spans exported");

    // (c) Job lifecycles on pid 1: queued precedes running on each
    // track, labels carry tenant + routine.
    let mut running = 0;
    for e in xs.iter().filter(|e| num(e, "pid") == 1.0) {
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        let args = e.get("args").expect("job event args");
        assert_eq!(args.get("routine").and_then(Json::as_str), Some("gemm"));
        assert!(args.get("tenant").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
        if name == "running" {
            running += 1;
            let tid = num(e, "tid");
            let queued = xs
                .iter()
                .find(|q| {
                    num(q, "pid") == 1.0
                        && num(q, "tid") == tid
                        && q.get("name").and_then(Json::as_str) == Some("queued")
                })
                .expect("running job without a queued span");
            let handoff = num(queued, "ts") + num(queued, "dur");
            assert!(
                (handoff - num(e, "ts")).abs() <= eps,
                "queued must end where running starts"
            );
        }
    }
    assert_eq!(running, CLIENTS * JOBS_PER_CLIENT, "one running span per admitted job");

    // The metrics registry saw the same story.
    let m = ctx.snapshot_metrics().expect("metrics");
    let retired = m.get("jobs_retired").and_then(Json::as_usize).unwrap_or(0);
    assert_eq!(retired, CLIENTS * JOBS_PER_CLIENT);
    assert!(m.get("per_routine").and_then(|r| r.get("gemm")).is_some());
}

/// Histogram percentiles must track the brute-force oracle
/// (`percentile_sorted` over all recorded samples) within the
/// log-bucket resolution, across a skewed distribution.
#[test]
fn histogram_percentiles_match_brute_force_oracle() {
    let mut h = Histogram::new();
    let mut p = Prng::new(4242);
    let mut u = vec![0.0f64; 4000];
    p.fill_f64(&mut u, 0.0, 1.0);
    // Skew: square the uniform draw and spread over ~5 decades.
    let vals: Vec<f64> = u.iter().map(|x| 1e-5 + x * x * 2.0).collect();
    for &v in &vals {
        h.record(v);
    }
    let mut sorted = vals.clone();
    sorted.sort_by(f64::total_cmp);

    assert_eq!(h.count(), vals.len() as u64);
    let mean_oracle = vals.iter().sum::<f64>() / vals.len() as f64;
    assert!((h.mean() - mean_oracle).abs() <= 1e-9 * vals.len() as f64, "mean is exact");

    for pct in [10.0, 50.0, 90.0, 95.0, 99.0] {
        let got = h.percentile(pct);
        let want = percentile_sorted(&sorted, pct);
        let rel = (got - want).abs() / want.abs().max(1e-12);
        assert!(
            rel <= 0.15,
            "p{pct}: histogram {got} vs oracle {want} (rel err {rel:.3})"
        );
    }
    // Percentiles are clamped to the observed range.
    assert!(h.percentile(0.0) >= sorted[0] * 0.999);
    assert!(h.percentile(100.0) <= sorted[sorted.len() - 1] * 1.001);
}

/// The exported Chrome trace document must be loadable by Perfetto:
/// parseable JSON, metadata first, complete events time-sorted with
/// non-negative ts/dur, and every event on a known pid/tid track.
#[test]
fn chrome_trace_export_is_golden_valid() {
    let ctx = traced_load();
    let text = ctx.chrome_trace_json().expect("chrome json");
    let doc = json::parse(&text).expect("chrome trace must parse");
    assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));

    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    // Metadata events lead the array and name both process tracks.
    let mut seen_x = false;
    let mut process_names = Vec::new();
    for e in events {
        match e.get("ph").and_then(Json::as_str) {
            Some("M") => {
                assert!(!seen_x, "metadata must precede all complete events");
                if e.get("name").and_then(Json::as_str) == Some("process_name") {
                    if let Some(n) =
                        e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str)
                    {
                        process_names.push(n.to_string());
                    }
                }
            }
            Some("X") => seen_x = true,
            ph => panic!("unexpected event phase {ph:?}"),
        }
    }
    assert!(seen_x, "trace has no complete events");
    assert!(process_names.iter().any(|n| n == "devices"));
    assert!(process_names.iter().any(|n| n == "jobs"));

    let xs = complete_events(&doc);
    let mut prev_ts = f64::NEG_INFINITY;
    for e in &xs {
        let (pid, ts, dur) = (num(e, "pid"), num(e, "ts"), num(e, "dur"));
        assert!(pid == 0.0 || pid == 1.0, "unknown pid track");
        if pid == 0.0 {
            assert!(num(e, "tid") < DEVICES as f64);
        }
        assert!(ts >= 0.0 && dur >= 0.0, "negative ts/dur");
        assert!(ts >= prev_ts, "complete events must be ts-sorted");
        prev_ts = ts;
    }
}

/// The disabled recorder is the default for every call — its probes
/// must not allocate at all (one relaxed atomic load per site, no
/// clock reads, no span pushes).
#[test]
fn disabled_recorder_records_without_allocating() {
    let rec = Recorder::new(DEVICES);
    rec.set_enabled(false);
    let _ = thread_allocs(); // warm the TLS slot outside the window
    let before = thread_allocs();
    for i in 0..10_000u64 {
        let t0 = rec.now();
        rec.record((i % DEVICES as u64) as usize, SpanKind::Kernel, t0, 128.0, i);
        rec.record((i % DEVICES as u64) as usize, SpanKind::Round, t0, 0.0, 0);
    }
    assert_eq!(thread_allocs(), before, "disabled recorder allocated");
    assert!(rec.spans().is_empty(), "disabled recorder must drop spans");

    // Flipping it on makes the same probes record (sanity that the
    // zero-allocation path is the *disabled* branch, not a stub).
    rec.set_enabled(true);
    let t0 = rec.now();
    rec.record(0, SpanKind::Kernel, t0, 1.0, 7);
    assert_eq!(rec.spans().len(), 1);
}
