//! Batch-subsystem correctness: the fused runtime against the looped
//! single-call reference.
//!
//! Two oracles, two guarantees:
//! - vs a loop of single `hostblas::gemm_blocked` calls the batch is
//!   numerically *close* (different blocking ⇒ different summation
//!   order, so tolerance-based);
//! - vs a loop of single `api::dgemm` calls through the same runtime
//!   (same kernel backend) the batch is **bit-for-bit identical**:
//!   fusion only renumbers tasks, so every C tile is produced by the
//!   exact same sequence of tile-kernel invocations.

use blasx::api::types::Trans;
use blasx::api::{self, Context, GemmBatchEntry};
use blasx::hostblas;
use blasx::util::prng::Prng;
use blasx::util::prop::{check_close, Cases};

fn ctx(t: usize) -> Context {
    Context::new(2).with_arena(4 << 20).with_tile(t)
}

/// Stored dims of op(X) given (rows, cols) of the op result.
fn stored(trans: Trans, r: usize, c: usize) -> (usize, usize) {
    if trans == Trans::No {
        (r, c)
    } else {
        (c, r)
    }
}

struct Problem {
    e: GemmBatchEntry,
    a: Vec<f64>,
    b: Vec<f64>,
    c: Vec<f64>,
}

/// A random variable-size batch with edge tiles (dims not multiples of
/// t), transposes, padded leading dims and alpha/beta corner cases.
fn random_batch(rng: &mut Prng, max_probs: usize, max_dim: usize) -> Vec<Problem> {
    let nprob = rng.range(1, max_probs);
    let alphas = [0.0, 1.0, -1.0, 1.3];
    let betas = [0.0, 1.0, -0.4];
    (0..nprob)
        .map(|_| {
            let m = rng.range(1, max_dim);
            let n = rng.range(1, max_dim);
            let k = rng.range(1, max_dim);
            let ta = if rng.chance(0.5) { Trans::No } else { Trans::Yes };
            let tb = if rng.chance(0.5) { Trans::No } else { Trans::Yes };
            let (asr, asc) = stored(ta, m, k);
            let (bsr, bsc) = stored(tb, k, n);
            // leading dims padded past the row count half the time
            let lda = asr + if rng.chance(0.5) { rng.below(4) } else { 0 };
            let ldb = bsr + if rng.chance(0.5) { rng.below(4) } else { 0 };
            let ldc = m + if rng.chance(0.5) { rng.below(4) } else { 0 };
            let e = GemmBatchEntry {
                ta,
                tb,
                m,
                n,
                k,
                alpha: alphas[rng.below(alphas.len())],
                beta: betas[rng.below(betas.len())],
                lda,
                ldb,
                ldc,
            };
            let mut a = vec![0.0; lda * asc];
            let mut b = vec![0.0; ldb * bsc];
            let mut c = vec![0.0; ldc * n];
            rng.fill_f64(&mut a, -1.0, 1.0);
            rng.fill_f64(&mut b, -1.0, 1.0);
            rng.fill_f64(&mut c, -1.0, 1.0);
            Problem { e, a, b, c }
        })
        .collect()
}

fn run_batched(ctx: &Context, probs: &mut [Problem]) {
    let entries: Vec<GemmBatchEntry> = probs.iter().map(|p| p.e).collect();
    // Move the C buffers out first so the mutable borrows don't fight
    // the shared A/B borrows of the same structs.
    let mut cbufs: Vec<Vec<f64>> = probs.iter_mut().map(|p| std::mem::take(&mut p.c)).collect();
    let arefs: Vec<&[f64]> = probs.iter().map(|p| p.a.as_slice()).collect();
    let brefs: Vec<&[f64]> = probs.iter().map(|p| p.b.as_slice()).collect();
    let mut crefs: Vec<&mut [f64]> = cbufs.iter_mut().map(Vec::as_mut_slice).collect();
    api::dgemm_batched(ctx, &entries, &arefs, &brefs, &mut crefs).expect("dgemm_batched");
    drop(crefs);
    for (p, c) in probs.iter_mut().zip(cbufs) {
        p.c = c;
    }
}

#[test]
fn batched_matches_looped_hostblas_property() {
    Cases::new(20).run("dgemm_batched vs looped hostblas", |rng| {
        // One engine per case: each case frees its randomly-shaped
        // operand buffers, and the persistent runtime's cross-call
        // cache contract requires input buffers to stay live (or be
        // declared via `invalidate_host`) between calls on one
        // context. Fresh contexts keep the cases independent.
        let ctx = ctx(16);
        let mut probs = random_batch(rng, 8, 50);
        let want: Vec<Vec<f64>> = probs
            .iter()
            .map(|p| {
                let mut w = p.c.clone();
                hostblas::gemm_blocked(
                    p.e.ta, p.e.tb, p.e.m, p.e.n, p.e.k, p.e.alpha, &p.a, p.e.lda, &p.b, p.e.ldb,
                    p.e.beta, &mut w, p.e.ldc,
                );
                w
            })
            .collect();
        run_batched(&ctx, &mut probs);
        for (i, (p, w)) in probs.iter().zip(&want).enumerate() {
            check_close(&p.c, w, 1e-10).map_err(|e| format!("problem {i}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn batched_64_problems_bitexact_vs_looped_single_calls() {
    // The acceptance bar: a 64-problem variable-size batch matches the
    // looped single-call reference bit-for-bit on the same backend.
    let ctx = ctx(32);
    let mut rng = Prng::new(2026);
    let mut probs = random_batch(&mut rng, 64, 96);
    while probs.len() < 64 {
        probs.extend(random_batch(&mut rng, 64 - probs.len(), 96));
    }
    probs.truncate(64);

    // looped single calls through the same runtime/context
    let looped: Vec<Vec<f64>> = probs
        .iter()
        .map(|p| {
            let mut c = p.c.clone();
            api::dgemm(
                &ctx, p.e.ta, p.e.tb, p.e.m, p.e.n, p.e.k, p.e.alpha, &p.a, p.e.lda, &p.b,
                p.e.ldb, p.e.beta, &mut c, p.e.ldc,
            )
            .expect("dgemm");
            c
        })
        .collect();

    run_batched(&ctx, &mut probs);
    for (i, (p, w)) in probs.iter().zip(&looped).enumerate() {
        assert_eq!(p.c, *w, "problem {i} diverged from the looped reference");
    }
}

#[test]
fn strided_matches_pointer_array_bitexact() {
    let ctx = ctx(16);
    let (m, n, k, batch) = (33usize, 20, 17, 6);
    let (lda, ldb, ldc) = (m + 2, k, m);
    let stride_a = lda * k + 5;
    let stride_b = ldb * n;
    let stride_c = ldc * n + 3;
    let mut rng = Prng::new(9);
    let mut a = vec![0.0; (batch - 1) * stride_a + lda * k];
    let mut b = vec![0.0; (batch - 1) * stride_b + ldb * n];
    let mut c = vec![0.0; (batch - 1) * stride_c + ldc * n];
    rng.fill_f64(&mut a, -1.0, 1.0);
    rng.fill_f64(&mut b, -1.0, 1.0);
    rng.fill_f64(&mut c, -1.0, 1.0);
    let c0 = c.clone();

    api::dgemm_batched_strided(
        &ctx, Trans::No, Trans::No, m, n, k, 0.9, &a, lda, stride_a, &b, ldb, stride_b, 0.3,
        &mut c, ldc, stride_c, batch,
    )
    .unwrap();

    // pointer-array over the same strides
    let entries = vec![
        GemmBatchEntry { ta: Trans::No, tb: Trans::No, m, n, k, alpha: 0.9, beta: 0.3, lda, ldb, ldc };
        batch
    ];
    let arefs: Vec<&[f64]> = (0..batch).map(|i| &a[i * stride_a..]).collect();
    let brefs: Vec<&[f64]> = (0..batch).map(|i| &b[i * stride_b..]).collect();
    let mut cexp = c0;
    let mut crefs: Vec<&mut [f64]> = Vec::new();
    let mut rest = cexp.as_mut_slice();
    for i in 0..batch {
        let cur = std::mem::take(&mut rest);
        if i + 1 == batch {
            crefs.push(cur);
        } else {
            let (head, tail) = cur.split_at_mut(stride_c);
            crefs.push(head);
            rest = tail;
        }
    }
    api::dgemm_batched(&ctx, &entries, &arefs, &brefs, &mut crefs).unwrap();
    drop(crefs);
    assert_eq!(c, cexp);
}

#[test]
fn strided_broadcast_shares_one_weight_matrix() {
    // stride_b == 0: every problem multiplies the same B (one weight
    // matrix against many activation blocks — the serving pattern).
    let ctx = ctx(16);
    let (m, n, k, batch) = (24usize, 18, 32, 5);
    let mut rng = Prng::new(11);
    let mut a = vec![0.0; batch * m * k];
    let mut b = vec![0.0; k * n];
    let mut c = vec![0.0; batch * m * n];
    rng.fill_f64(&mut a, -1.0, 1.0);
    rng.fill_f64(&mut b, -1.0, 1.0);

    api::dgemm_batched_strided(
        &ctx, Trans::No, Trans::No, m, n, k, 1.0, &a, m, m * k, &b, k, 0, 0.0, &mut c, m, m * n,
        batch,
    )
    .unwrap();

    for i in 0..batch {
        let mut want = vec![0.0; m * n];
        hostblas::gemm_blocked(
            Trans::No, Trans::No, m, n, k, 1.0, &a[i * m * k..(i + 1) * m * k], m, &b, k, 0.0,
            &mut want, m,
        );
        let got = &c[i * m * n..(i + 1) * m * n];
        let diff = got.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "problem {i}: {diff}");
    }
}

#[test]
fn batched_error_paths() {
    let ctx = ctx(16);
    // bad leading dimension inside one entry poisons the whole batch
    let bad = GemmBatchEntry { lda: 2, ..GemmBatchEntry::new(8, 8, 8, 1.0, 0.0) };
    let a = vec![0.0f64; 64];
    let b = vec![0.0f64; 64];
    let mut c = vec![0.0f64; 64];
    let mut crefs: Vec<&mut [f64]> = vec![c.as_mut_slice()];
    assert!(api::dgemm_batched(&ctx, &[bad], &[&a], &[&b], &mut crefs).is_err());

    // overlapping C strides are rejected
    let mut cc = vec![0.0f64; 8 * 8 * 2];
    let err = api::dgemm_batched_strided(
        &ctx, Trans::No, Trans::No, 8, 8, 8, 1.0, &a, 8, 64, &b, 8, 64, 0.0, &mut cc, 8, 10, 2,
    );
    assert!(err.is_err());

    // empty batch is a no-op success
    let mut none: Vec<&mut [f64]> = Vec::new();
    assert!(api::dgemm_batched(&ctx, &[], &[], &[], &mut none).is_ok());
}
