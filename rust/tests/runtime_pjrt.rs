//! Integration: artifacts → PJRT → numbers, against the hostblas oracle.
//!
//! Requires `make artifacts` to have populated `artifacts/` (the Makefile
//! `test` target guarantees this). These tests exercise the exact bridge
//! the coordinator's real mode uses: HLO text → compile → execute.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::Dtype;
use blasx::hostblas;
use blasx::runtime::TileExecutor;
use blasx::util::prng::Prng;

const T: usize = 64;

fn rand_tile(p: &mut Prng) -> Vec<f64> {
    let mut v = vec![0.0; T * T];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn gemm_nn_matches_hostblas() {
    let ex = TileExecutor::new().expect("pjrt client");
    let mut p = Prng::new(42);
    let a = rand_tile(&mut p);
    let b = rand_tile(&mut p);
    let c0 = rand_tile(&mut p);

    let mut c = c0.clone();
    ex.run("gemm_nn", T, Some(&a), Some(&b), &mut c, 1.5, -0.5).unwrap();

    let mut want = c0;
    hostblas::gemm_blocked(
        Trans::No, Trans::No, T, T, T, 1.5, &a, T, &b, T, -0.5, &mut want, T,
    );
    assert!(max_abs_diff(&c, &want) < 1e-10, "diff {}", max_abs_diff(&c, &want));
}

#[test]
fn gemm_transposed_variants_match() {
    let ex = TileExecutor::new().unwrap();
    let mut p = Prng::new(7);
    let a = rand_tile(&mut p);
    let b = rand_tile(&mut p);
    let c0 = rand_tile(&mut p);

    for (name, ta, tb) in [
        ("gemm_nt", Trans::No, Trans::Yes),
        ("gemm_tn", Trans::Yes, Trans::No),
        ("gemm_tt", Trans::Yes, Trans::Yes),
    ] {
        let mut c = c0.clone();
        ex.run(name, T, Some(&a), Some(&b), &mut c, 2.0, 1.0).unwrap();
        let mut want = c0.clone();
        hostblas::gemm_blocked(ta, tb, T, T, T, 2.0, &a, T, &b, T, 1.0, &mut want, T);
        assert!(max_abs_diff(&c, &want) < 1e-10, "{name}: diff {}", max_abs_diff(&c, &want));
    }
}

#[test]
fn syrk_diag_matches_hostblas() {
    let ex = TileExecutor::new().unwrap();
    let mut p = Prng::new(13);
    let a = rand_tile(&mut p);
    let c0 = rand_tile(&mut p);

    let mut c = c0.clone();
    ex.run("syrk_up_n", T, Some(&a), None, &mut c, 0.7, 1.1).unwrap();

    // Oracle: full symmetric product via gemm (the artifact computes the
    // whole tile; the triangle mask is applied at write-back, not here).
    let mut want = c0;
    hostblas::gemm_blocked(Trans::No, Trans::Yes, T, T, T, 0.7, &a, T, &a, T, 1.1, &mut want, T);
    assert!(max_abs_diff(&c, &want) < 1e-10);
}

#[test]
fn trsm_diag_solves() {
    let ex = TileExecutor::new().unwrap();
    let mut p = Prng::new(99);
    // Well-conditioned triangular tile: damp off-diagonal, boost diagonal.
    let mut a = rand_tile(&mut p);
    for x in a.iter_mut() {
        *x *= 0.1;
    }
    for i in 0..T {
        a[i * T + i] = 2.0 + 0.1 * (i as f64 / T as f64);
    }
    let c0 = rand_tile(&mut p);

    let mut x = c0.clone();
    ex.run("trsm_l_up_n_nu", T, Some(&a), None, &mut x, 1.0, 0.0).unwrap();

    // Residual check against the defining equation: triu(A) * X = C.
    let mut ax = vec![0.0; T * T];
    let mut a_up = vec![0.0; T * T];
    for j in 0..T {
        for i in 0..=j {
            a_up[j * T + i] = a[j * T + i];
        }
    }
    hostblas::gemm_blocked(Trans::No, Trans::No, T, T, T, 1.0, &a_up, T, &x, T, 0.0, &mut ax, T);
    assert!(max_abs_diff(&ax, &c0) < 1e-9, "residual {}", max_abs_diff(&ax, &c0));
}

#[test]
fn trmm_symm_scal_match_reference() {
    let ex = TileExecutor::new().unwrap();
    let mut p = Prng::new(5);
    let a = rand_tile(&mut p);
    let b = rand_tile(&mut p);
    let c0 = rand_tile(&mut p);

    // trmm_l_lo_n_nu: C := 1.5 * tril(A) @ C
    let mut c = c0.clone();
    ex.run("trmm_l_lo_n_nu", T, Some(&a), None, &mut c, 1.5, 0.0).unwrap();
    let mut want = c0.clone();
    hostblas::trmm_ref(Side::Left, Uplo::Lower, Trans::No, Diag::NonUnit, T, T, 1.5, &a, T, &mut want, T);
    assert!(max_abs_diff(&c, &want) < 1e-10);

    // symm_l_up
    let mut c = c0.clone();
    ex.run("symm_l_up", T, Some(&a), Some(&b), &mut c, 0.3, -0.2).unwrap();
    let mut want = c0.clone();
    hostblas::symm_ref(Side::Left, Uplo::Upper, T, T, 0.3, &a, T, &b, T, -0.2, &mut want, T);
    assert!(max_abs_diff(&c, &want) < 1e-10);

    // scal
    let mut c = c0.clone();
    ex.run("scal", T, None, None, &mut c, 0.0, 0.25).unwrap();
    let want: Vec<f64> = c0.iter().map(|x| 0.25 * x).collect();
    assert!(max_abs_diff(&c, &want) < 1e-15);
}

#[test]
fn f32_path_works() {
    let ex = TileExecutor::new().unwrap();
    let mut p = Prng::new(21);
    let mut a = vec![0.0f32; T * T];
    let mut b = vec![0.0f32; T * T];
    let mut c = vec![0.0f32; T * T];
    p.fill_f32(&mut a, -1.0, 1.0);
    p.fill_f32(&mut b, -1.0, 1.0);
    p.fill_f32(&mut c, -1.0, 1.0);
    let c0 = c.clone();
    ex.run("gemm_nn", T, Some(&a), Some(&b), &mut c, 1.0f32, 0.0f32).unwrap();
    let mut want = c0;
    hostblas::gemm_blocked(Trans::No, Trans::No, T, T, T, 1.0f32, &a, T, &b, T, 0.0f32, &mut want, T);
    let diff = c.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(diff < 1e-3, "diff {diff}");
}

#[test]
fn executables_are_cached() {
    let ex = TileExecutor::new().unwrap();
    let pool = blasx::runtime::PjrtPool::global().unwrap();
    let before = pool.cached();
    let mut p = Prng::new(3);
    let a = rand_tile(&mut p);
    let b = rand_tile(&mut p);
    let mut c = rand_tile(&mut p);
    ex.run("gemm_nn", T, Some(&a), Some(&b), &mut c, 1.0, 1.0).unwrap();
    let mid = pool.cached();
    ex.run("gemm_nn", T, Some(&a), Some(&b), &mut c, 2.0, 0.5).unwrap();
    assert_eq!(pool.cached(), mid, "second run must not recompile");
    assert!(mid >= before);
}

#[test]
fn missing_artifact_reports_cleanly() {
    let ex = TileExecutor::new().unwrap();
    assert!(!ex.available("gemm_nn", Dtype::F64, 123));
    let mut c = vec![0.0; 9];
    let err = ex.run::<f64>("gemm_nn", 3, Some(&c.clone()), Some(&c.clone()), &mut c, 1.0, 1.0);
    assert!(err.is_err());
}
