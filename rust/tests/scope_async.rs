//! The scoped-async API's guarantees: cross-job aliasing chains
//! (RAW/WAR/WAW ordered by the admission table, bit-for-bit equal to
//! serial), and soundness of the scope-close barrier — `mem::forget`
//! on a handle, early handle drops, and panicking closures must all
//! leave the scope waiting for every job before the operand borrows
//! end.
//!
//! Run under both the default test harness and `RUST_TEST_THREADS=1`
//! (CI does both).

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::api::{self, Context};
use blasx::coordinator::Backend;
use blasx::util::prng::Prng;

fn ctx() -> Context {
    Context::new(2).with_arena(8 << 20).with_tile(32)
}

fn rand(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut v = vec![0.0; n];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

fn upper_tri(p: &mut Prng, n: usize) -> Vec<f64> {
    let mut a = rand(p, n * n);
    for x in a.iter_mut() {
        *x *= 0.5 / (n as f64).sqrt();
    }
    for i in 0..n {
        a[i * n + i] = 2.0;
    }
    a
}

/// RAW chain across two in-flight jobs: E := (A·B)·D. The second job
/// reads the buffer the first is still writing; the admission edge
/// orders them, and the result is bit-for-bit the serial sequence's.
#[test]
fn raw_chain_through_one_scope() {
    let c = ctx();
    let n = 96;
    let mut p = Prng::new(1);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let d = rand(&mut p, n * n);
    let mut x = vec![0.0; n * n];
    let mut e = vec![0.0; n * n];
    c.scope(|s| {
        let (ra, rb, rd) = (s.input(&a), s.input(&b), s.input(&d));
        let rx = s.buffer(&mut x);
        let re = s.buffer(&mut e);
        let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rx, n)?;
        // rx is an INPUT here — same token, no new borrow needed.
        let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, rx, n, rd, n, 0.0, re, n)?;
        Ok(())
    })
    .unwrap();

    let serial = ctx().with_persistent(false);
    let mut want_x = vec![0.0; n * n];
    let mut want_e = vec![0.0; n * n];
    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want_x, n)
        .unwrap();
    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.0, &want_x, n, &d, n, 0.0, &mut want_e, n)
        .unwrap();
    assert_eq!(x, want_x, "first link diverged");
    assert_eq!(e, want_e, "RAW chain diverged from serial");
}

/// WAR pair: job 1 reads X (into Y), job 2 then overwrites X. Job 2
/// must not clobber X before job 1 has consumed it.
#[test]
fn war_pair_orders_by_admission() {
    let c = ctx();
    let n = 64;
    let mut p = Prng::new(2);
    let x0 = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let g = rand(&mut p, n * n);
    let h = rand(&mut p, n * n);
    let mut x = x0.clone();
    let mut y = vec![0.0; n * n];
    c.scope(|s| {
        let (rb, rg, rh) = (s.input(&b), s.input(&g), s.input(&h));
        let rx = s.buffer(&mut x);
        let ry = s.buffer(&mut y);
        // reader first …
        let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, rx, n, rb, n, 0.0, ry, n)?;
        // … then a writer of the same buffer (WAR edge)
        let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, rg, n, rh, n, 0.0, rx, n)?;
        Ok(())
    })
    .unwrap();

    let serial = ctx().with_persistent(false);
    let mut want_y = vec![0.0; n * n];
    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.0, &x0, n, &b, n, 0.0, &mut want_y, n)
        .unwrap();
    let mut want_x = vec![0.0; n * n];
    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.0, &g, n, &h, n, 0.0, &mut want_x, n)
        .unwrap();
    assert_eq!(y, want_y, "reader saw the overwritten X (WAR violated)");
    assert_eq!(x, want_x, "writer's result lost");
}

/// WAW pair: two jobs write the same C; the later admission must win,
/// exactly as in the serial sequence.
#[test]
fn waw_pair_orders_by_admission() {
    let c = ctx();
    let n = 64;
    let mut p = Prng::new(3);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let g = rand(&mut p, n * n);
    let h = rand(&mut p, n * n);
    let mut out = vec![0.0; n * n];
    c.scope(|s| {
        let (ra, rb, rg, rh) = (s.input(&a), s.input(&b), s.input(&g), s.input(&h));
        let ro = s.buffer(&mut out);
        let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, ro, n)?;
        let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, rg, n, rh, n, 0.0, ro, n)?;
        Ok(())
    })
    .unwrap();
    let serial = ctx().with_persistent(false);
    let mut want = vec![0.0; n * n];
    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.0, &g, n, &h, n, 0.0, &mut want, n)
        .unwrap();
    assert_eq!(out, want, "later WAW writer must win");
}

/// The forget-safety property the old wait-on-drop API lacked:
/// `std::mem::forget` on a live handle inside the scope must not skip
/// the completion barrier — the scope close still waits, so the
/// buffers hold the finished results and workers never touch freed
/// memory after the scope returns.
#[test]
fn forgotten_handle_still_completes_at_scope_close() {
    let c = ctx();
    let n = 128; // big enough that the job genuinely outlives the forget
    for round in 0..4 {
        let a = vec![1.0; n * n];
        let b = vec![1.0; n * n];
        let mut out = vec![0.0; n * n];
        c.invalidate_host(&a);
        c.invalidate_host(&b);
        c.scope(|s| {
            let (ra, rb) = (s.input(&a), s.input(&b));
            let ro = s.buffer(&mut out);
            let h = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, ro, n)?;
            std::mem::forget(h);
            Ok(())
        })
        .unwrap();
        assert!(
            out.iter().all(|&x| x == n as f64),
            "round {round}: scope close must wait for the forgotten handle's job"
        );
        assert_eq!(c.jobs_in_flight(), 0, "round {round}");
        // a/b/out drop and are reallocated next round: if a worker were
        // still writing after scope close, later rounds would corrupt.
    }
}

/// A panicking closure must not unwind past in-flight jobs: the
/// ScopeToken's drop runs the same barrier, so by the time the panic
/// propagates out of `scope`, every job has retired.
#[test]
fn panicking_scope_still_waits_for_jobs() {
    let c = ctx();
    let n = 128;
    let a = vec![1.0; n * n];
    let b = vec![1.0; n * n];
    let mut out = vec![0.0; n * n];
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        c.scope(|s| -> blasx::Result<()> {
            let (ra, rb) = (s.input(&a), s.input(&b));
            let ro = s.buffer(&mut out);
            let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, ro, n)?;
            panic!("user closure panics with a job in flight");
        })
    }));
    assert!(result.is_err(), "the panic must propagate");
    assert!(
        out.iter().all(|&x| x == n as f64),
        "unwind path must still run the completion barrier"
    );
    assert_eq!(c.jobs_in_flight(), 0);
}

/// Mixed-routine aliasing chain through one scope: C := A·B, S := C'C
/// (syrk reads C), then solve T·X = C in place — three jobs, RAW edges
/// C→syrk and C→trsm, WAR syrk→trsm... all ordered by admission,
/// bit-for-bit serial.
#[test]
fn mixed_routine_chain_matches_serial() {
    let c = ctx();
    let n = 64;
    let mut p = Prng::new(5);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let tri = upper_tri(&mut p, n);
    let mut prod = vec![0.0; n * n];
    let mut sym = rand(&mut p, n * n);
    let sym0 = sym.clone();
    c.scope(|s| {
        let (ra, rb, rt) = (s.input(&a), s.input(&b), s.input(&tri));
        let rp = s.buffer(&mut prod);
        let rs = s.buffer(&mut sym);
        let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, rp, n)?;
        let _ = s.dsyrk(Uplo::Lower, Trans::No, n, n, 0.7, rp, n, 0.4, rs, n)?;
        let _ = s.dtrsm(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, rt, n, rp, n)?;
        Ok(())
    })
    .unwrap();

    let serial = ctx().with_persistent(false);
    let mut want_prod = vec![0.0; n * n];
    let mut want_sym = sym0;
    api::dgemm(&serial, Trans::No, Trans::No, n, n, n, 1.0, &a, n, &b, n, 0.0, &mut want_prod, n)
        .unwrap();
    api::syrk(&serial, Uplo::Lower, Trans::No, n, n, 0.7, &want_prod, n, 0.4, &mut want_sym, n)
        .unwrap();
    api::trsm(&serial, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, n, 1.0, &tri, n, &mut want_prod, n)
        .unwrap();
    assert_eq!(prod, want_prod, "dgemm→dtrsm in-place chain diverged");
    assert_eq!(sym, want_sym, "interleaved syrk diverged");
}

/// A detached (or forgotten) job's failure must surface at the scope
/// close — `scope` returning Ok over a garbage output buffer would be
/// a silent-error hole. A failure the user already observed via
/// `wait()` is NOT re-reported. (The PJRT backend is a deterministic
/// failure injector here: the offline xla stub errors on first use.)
#[test]
fn detached_job_failure_surfaces_at_scope_close() {
    let c = ctx().with_backend(Backend::Pjrt);
    let n = 64;
    let a = vec![1.0; n * n];
    let b = vec![1.0; n * n];
    let mut out = vec![0.0; n * n];
    let res = c.scope(|s| {
        let (ra, rb) = (s.input(&a), s.input(&b));
        let ro = s.buffer(&mut out);
        let _ = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, ro, n)?;
        Ok(())
    });
    assert!(res.is_err(), "detached failing job must fail the scope");

    // Same failure, but waited: delivered through the handle, so the
    // scope itself succeeds with the closure's value.
    let mut out2 = vec![0.0; n * n];
    let res2 = c.scope(|s| {
        let (ra, rb) = (s.input(&a), s.input(&b));
        let ro = s.buffer(&mut out2);
        let h = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, ro, n)?;
        assert!(h.wait().is_err(), "the job itself still fails");
        Ok(7u32)
    });
    assert_eq!(res2.unwrap(), 7, "observed failure must not re-surface at close");
    assert_eq!(c.jobs_in_flight(), 0);
}

/// Handles observe per-job completion (`is_done`, out-of-order waits)
/// and carry per-job reports.
#[test]
fn handles_report_per_job() {
    let c = ctx();
    let n = 64;
    let mut p = Prng::new(6);
    let a = rand(&mut p, n * n);
    let b = rand(&mut p, n * n);
    let mut o1 = vec![0.0; n * n];
    let mut o2 = vec![0.0; n * n];
    c.scope(|s| {
        let (ra, rb) = (s.input(&a), s.input(&b));
        let r1 = s.buffer(&mut o1);
        let r2 = s.buffer(&mut o2);
        let h1 = s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, r1, n)?;
        let h2 = s.dgemm(Trans::Yes, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, r2, n)?;
        assert_ne!(h1.job_id(), h2.job_id());
        let rep2 = h2.wait()?;
        assert!(rep2.transfers.total_host_reads() > 0 || rep2.transfers.l1_hits > 0);
        let rep1 = h1.wait()?;
        assert!(rep1.tasks_per_device.iter().sum::<usize>() > 0);
        Ok(())
    })
    .unwrap();
    assert_eq!(c.runtime_calls(), 2);
}

/// Scopes compose: sequential scopes on one context, concurrent scopes
/// on clones from different threads, and f32 jobs share the fleet.
#[test]
fn scopes_compose_across_threads_and_dtypes() {
    let c = ctx();
    // empty scope is a no-op
    c.scope(|_s| Ok(())).unwrap();
    std::thread::scope(|ts| {
        let c1 = c.clone();
        ts.spawn(move || {
            let n = 48;
            let a = vec![2.0f64; n * n];
            let b = vec![1.0f64; n * n];
            let mut o = vec![0.0f64; n * n];
            c1.scope(|s| {
                let (ra, rb) = (s.input(&a), s.input(&b));
                let ro = s.buffer(&mut o);
                s.dgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, ro, n)
                    .map(|h| h.detach())
            })
            .unwrap();
            assert!(o.iter().all(|&x| x == 2.0 * n as f64));
        });
        let c2 = c.clone();
        ts.spawn(move || {
            let n = 56;
            let a = vec![1.0f32; n * n];
            let b = vec![3.0f32; n * n];
            let mut o = vec![0.0f32; n * n];
            c2.scope(|s| {
                let (ra, rb) = (s.input(&a), s.input(&b));
                let ro = s.buffer(&mut o);
                s.sgemm(Trans::No, Trans::No, n, n, n, 1.0, ra, n, rb, n, 0.0, ro, n)
                    .map(|h| h.detach())
            })
            .unwrap();
            assert!(o.iter().all(|&x| x == 3.0 * n as f32));
        });
    });
    assert_eq!(c.jobs_in_flight(), 0);
}
