//! Property tests on the two-level tile cache and the FastHeap: random
//! operation sequences must preserve every structural invariant (list ↔
//! map consistency, directory ↔ ALRU agreement, heap non-overlap and
//! full coalescing).

use blasx::cache::{Source, TileCacheSet};
use blasx::mem::{AllocStrategy, FastHeap};
use blasx::tile::{MatId, TileKey};
use blasx::util::prop::Cases;

fn key(i: usize) -> TileKey {
    TileKey::synthetic(0x1000 + i * 64, MatId::A, i, 0)
}

#[test]
fn tile_cache_random_ops_hold_invariants() {
    Cases::new(120).run("tile_cache_ops", |rng| {
        let n_dev = rng.range(1, 5);
        // all-peers topology stresses the L2 path hardest
        let peers: Vec<Vec<usize>> =
            (0..n_dev).map(|d| (0..n_dev).filter(|&x| x != d).collect()).collect();
        let cap = 64 * (2 + rng.below(6)); // 2..7 blocks of 64 bytes
        let mut set = TileCacheSet::new(&vec![cap; n_dev], peers, AllocStrategy::FastHeap);
        let n_keys = rng.range(3, 12);
        // readers[dev][key] = outstanding acquire count we must release
        let mut readers = vec![vec![0u32; n_keys]; n_dev];

        for _ in 0..400 {
            let d = rng.below(n_dev);
            let k = rng.below(n_keys);
            match rng.below(4) {
                0 | 1 => {
                    // acquire (reads)
                    if let Some(acq) = set.acquire(d, key(k), 64) {
                        readers[d][k] += 1;
                        if let Source::Peer { src, .. } = acq.source {
                            if src == d {
                                return Err("self peer".into());
                            }
                        }
                    }
                }
                2 => {
                    // release one outstanding reader
                    if readers[d][k] > 0 {
                        set.release(d, &key(k));
                        readers[d][k] -= 1;
                    }
                }
                _ => {
                    // write-back invalidation (M -> I)
                    set.writeback(d, &key(k));
                    // outstanding readers remain legal (doomed blocks)
                }
            }
            set.validate().map_err(|e| format!("validate: {e}"))?;
            // directory holders must actually be resident or doomed
            for kk in 0..n_keys {
                for &h in set.dir.holders(&key(kk)) {
                    if h >= n_dev {
                        return Err(format!("holder {h} out of range"));
                    }
                }
            }
        }
        // drain all readers; caches must stay consistent
        for d in 0..n_dev {
            for k in 0..n_keys {
                for _ in 0..readers[d][k] {
                    set.release(d, &key(k));
                }
            }
        }
        set.validate().map_err(|e| format!("final validate: {e}"))
    });
}

#[test]
fn locality_scores_track_directory() {
    Cases::new(60).run("locality_scores", |rng| {
        let n_dev = 3;
        let peers = vec![vec![1, 2], vec![0, 2], vec![0, 1]];
        let mut set = TileCacheSet::new(&vec![1 << 12; n_dev], peers, AllocStrategy::FastHeap);
        let k = key(rng.below(4));
        let d = rng.below(n_dev);
        assert_eq!(set.locality_score(d, &k), 0);
        set.acquire(d, k, 64).ok_or("acquire failed")?;
        if set.locality_score(d, &k) != 2 {
            return Err("own copy must score 2".into());
        }
        let other = (d + 1) % n_dev;
        if set.locality_score(other, &k) != 1 {
            return Err("peer copy must score 1".into());
        }
        set.writeback(d, &k);
        if set.locality_score(other, &k) != 0 {
            return Err("invalidated copy must score 0".into());
        }
        Ok(())
    });
}

#[test]
fn fast_heap_random_alloc_free_never_overlaps() {
    Cases::new(100).run("fast_heap", |rng| {
        let cap = 1 << 14;
        let mut heap = FastHeap::new(cap);
        let mut live: Vec<(usize, usize)> = Vec::new(); // (offset, len)
        for _ in 0..300 {
            if rng.chance(0.55) {
                let len = 16 << rng.below(6); // 16..512
                if let Some(off) = heap.alloc(len) {
                    // no overlap with any live block
                    for &(o, l) in &live {
                        if off < o + l && o < off + len {
                            return Err(format!("overlap: [{off},{len}] vs [{o},{l}]"));
                        }
                    }
                    live.push((off, len));
                }
            } else if !live.is_empty() {
                let i = rng.below(live.len());
                let (off, _) = live.swap_remove(i);
                heap.free(off);
            }
            heap.validate().map_err(|e| format!("validate: {e}"))?;
        }
        // free everything: heap must fully coalesce
        for (off, _) in live.drain(..) {
            heap.free(off);
        }
        if heap.in_use() != 0 {
            return Err(format!("leak: {} bytes in use", heap.in_use()));
        }
        if heap.largest_free() != cap {
            return Err(format!("fragmentation left: largest {} != {cap}", heap.largest_free()));
        }
        Ok(())
    });
}

#[test]
fn real_engine_random_gemm_property() {
    use blasx::api::types::Trans;
    use blasx::coordinator::real_engine::{run_real, Mats};
    use blasx::coordinator::RunConfig;
    use blasx::hostblas;
    use blasx::task::{taskize_gemm, GemmDesc};
    use blasx::tile::HostMat;

    Cases::new(20).run("real_gemm", |rng| {
        let t = 32;
        let m = rng.range(16, 100);
        let n = rng.range(16, 100);
        let k = rng.range(16, 100);
        let ta = if rng.chance(0.5) { Trans::No } else { Trans::Yes };
        let tb = if rng.chance(0.5) { Trans::No } else { Trans::Yes };
        let alpha = rng.range_f64(-2.0, 2.0);
        let beta = rng.range_f64(-2.0, 2.0);
        let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
        let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
        let mut a = vec![0.0; ar * ac];
        let mut b = vec![0.0; br * bc];
        let mut c = vec![0.0; m * n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        rng.fill_f64(&mut b, -1.0, 1.0);
        rng.fill_f64(&mut c, -1.0, 1.0);
        let mut want = c.clone();

        let d = GemmDesc { ta, tb, m, n, k, alpha, beta, t };
        let ts = taskize_gemm(&d);
        let am = HostMat::new_ro(&a, ar, ac, ar, t, MatId::A);
        let bm = HostMat::new_ro(&b, br, bc, br, t, MatId::B);
        let cm = HostMat::new(&mut c, m, n, m, t, MatId::C);
        let cfg = RunConfig { t, ..Default::default() };
        let n_dev = rng.range(1, 4);
        run_real(&cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, n_dev, 16 * t * t * 8)
            .map_err(|e| e.to_string())?;

        hostblas::gemm_blocked(ta, tb, m, n, k, alpha, &a, ar, &b, br, beta, &mut want, m);
        blasx::util::prop::check_close(&c, &want, 1e-9)
    });
}
