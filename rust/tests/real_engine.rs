//! Integration: the threaded real engine computes correct numerics for
//! every routine, under cache pressure, stealing, chains, and both
//! kernel backends.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::coordinator::real_engine::{run_real, Mats};
use blasx::coordinator::{Backend, RunConfig};
use blasx::hostblas;
use blasx::task::{
    taskize_gemm, taskize_symm, taskize_syr2k, taskize_syrk, taskize_trmm, taskize_trsm,
    GemmDesc, SymmDesc, SyrkDesc, TriDesc,
};
use blasx::tile::{HostMat, MatId};
use blasx::util::prng::Prng;

const T: usize = 32;

fn rand_mat(p: &mut Prng, rows: usize, cols: usize) -> Vec<f64> {
    let mut v = vec![0.0; rows * cols];
    p.fill_f64(&mut v, -1.0, 1.0);
    v
}

fn max_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn cfg(n_tiles_arena: usize) -> (RunConfig, usize) {
    let cfg = RunConfig { t: T, ..Default::default() };
    (cfg, n_tiles_arena * T * T * 8)
}

#[test]
fn gemm_matches_reference_various_shapes() {
    for (m, n, k) in [(96, 96, 96), (100, 70, 50), (33, 65, 97), (32, 32, 32)] {
        let mut p = Prng::new(1);
        let a = rand_mat(&mut p, m, k);
        let b = rand_mat(&mut p, k, n);
        let mut c = rand_mat(&mut p, m, n);
        let mut want = c.clone();

        let d = GemmDesc { ta: Trans::No, tb: Trans::No, m, n, k, alpha: 1.3, beta: -0.4, t: T };
        let ts = taskize_gemm(&d);
        let am = HostMat::new_ro(&a, m, k, m, T, MatId::A);
        let bm = HostMat::new_ro(&b, k, n, k, T, MatId::B);
        let cm = HostMat::new(&mut c, m, n, m, T, MatId::C);
        let (cfg, arena) = cfg(16);
        run_real(&cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, 2, arena).unwrap();

        hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.3, &a, m, &b, k, -0.4, &mut want, m);
        assert!(max_diff(&c, &want) < 1e-10, "({m},{n},{k}): {}", max_diff(&c, &want));
    }
}

#[test]
fn gemm_transposes_match() {
    let (m, n, k) = (70, 60, 50);
    for (ta, tb) in [(Trans::Yes, Trans::No), (Trans::No, Trans::Yes), (Trans::Yes, Trans::Yes)] {
        let mut p = Prng::new(2);
        let (ar, ac) = if ta == Trans::Yes { (k, m) } else { (m, k) };
        let (br, bc) = if tb == Trans::Yes { (n, k) } else { (k, n) };
        let a = rand_mat(&mut p, ar, ac);
        let b = rand_mat(&mut p, br, bc);
        let mut c = rand_mat(&mut p, m, n);
        let mut want = c.clone();

        let d = GemmDesc { ta, tb, m, n, k, alpha: 0.7, beta: 0.2, t: T };
        let ts = taskize_gemm(&d);
        let am = HostMat::new_ro(&a, ar, ac, ar, T, MatId::A);
        let bm = HostMat::new_ro(&b, br, bc, br, T, MatId::B);
        let cm = HostMat::new(&mut c, m, n, m, T, MatId::C);
        let (cfg, arena) = cfg(16);
        run_real(&cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, 2, arena).unwrap();

        hostblas::gemm_blocked(ta, tb, m, n, k, 0.7, &a, ar, &b, br, 0.2, &mut want, m);
        assert!(max_diff(&c, &want) < 1e-10, "({ta:?},{tb:?}): {}", max_diff(&c, &want));
    }
}

#[test]
fn syrk_syr2k_match_reference() {
    let (n, k) = (80, 60);
    for uplo in [Uplo::Upper, Uplo::Lower] {
        for trans in [Trans::No, Trans::Yes] {
            let mut p = Prng::new(3);
            let (ar, ac) = if trans == Trans::Yes { (k, n) } else { (n, k) };
            let a = rand_mat(&mut p, ar, ac);
            let b = rand_mat(&mut p, ar, ac);
            let mut c = rand_mat(&mut p, n, n);
            let mut want = c.clone();

            // SYRK
            let d = SyrkDesc { uplo, trans, n, k, alpha: 1.1, beta: 0.6, t: T };
            let ts = taskize_syrk(&d);
            let am = HostMat::new_ro(&a, ar, ac, ar, T, MatId::A);
            let cm = HostMat::new(&mut c, n, n, n, T, MatId::C);
            let (cfg, arena) = cfg(16);
            run_real(&cfg, &ts, Mats { a: &am, b: None, c: &cm }, 2, arena).unwrap();
            hostblas::syrk_ref(uplo, trans, n, k, 1.1, &a, ar, 0.6, &mut want, n);
            assert!(max_diff(&c, &want) < 1e-10, "syrk {uplo:?} {trans:?}");

            // SYR2K
            let mut c2 = rand_mat(&mut p, n, n);
            let mut want2 = c2.clone();
            let ts2 = taskize_syr2k(&d);
            let bm = HostMat::new_ro(&b, ar, ac, ar, T, MatId::B);
            let cm2 = HostMat::new(&mut c2, n, n, n, T, MatId::C);
            run_real(&cfg, &ts2, Mats { a: &am, b: Some(&bm), c: &cm2 }, 2, arena).unwrap();
            hostblas::syr2k_ref(uplo, trans, n, k, 1.1, &a, ar, &b, ar, 0.6, &mut want2, n);
            assert!(max_diff(&c2, &want2) < 1e-10, "syr2k {uplo:?} {trans:?}");
        }
    }
}

#[test]
fn symm_matches_reference() {
    let (m, n) = (70, 90);
    for side in [Side::Left, Side::Right] {
        for uplo in [Uplo::Upper, Uplo::Lower] {
            let mut p = Prng::new(4);
            let na = if side == Side::Left { m } else { n };
            let a = rand_mat(&mut p, na, na);
            let b = rand_mat(&mut p, m, n);
            let mut c = rand_mat(&mut p, m, n);
            let mut want = c.clone();

            let d = SymmDesc { side, uplo, m, n, alpha: -0.8, beta: 0.3, t: T };
            let ts = taskize_symm(&d);
            let am = HostMat::new_ro(&a, na, na, na, T, MatId::A);
            let bm = HostMat::new_ro(&b, m, n, m, T, MatId::B);
            let cm = HostMat::new(&mut c, m, n, m, T, MatId::C);
            let (cfg, arena) = cfg(16);
            run_real(&cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, 2, arena).unwrap();

            hostblas::symm_ref(side, uplo, m, n, -0.8, &a, na, &b, m, 0.3, &mut want, m);
            assert!(max_diff(&c, &want) < 1e-10, "symm {side:?} {uplo:?}");
        }
    }
}

#[test]
fn trmm_trsm_chains_match_reference() {
    let (m, n) = (96, 64);
    for side in [Side::Left, Side::Right] {
        for uplo in [Uplo::Upper, Uplo::Lower] {
            for ta in [Trans::No, Trans::Yes] {
                let mut p = Prng::new(5);
                let na = if side == Side::Left { m } else { n };
                // well-conditioned triangular operand
                let mut a = rand_mat(&mut p, na, na);
                for x in a.iter_mut() {
                    *x *= 0.5 / (na as f64).sqrt();
                }
                for i in 0..na {
                    a[i * na + i] = 2.0;
                }

                // TRMM
                let mut b = rand_mat(&mut p, m, n);
                let mut want = b.clone();
                let d = TriDesc { side, uplo, ta, diag: Diag::NonUnit, m, n, alpha: 1.4, t: T };
                let ts = taskize_trmm(&d);
                ts.validate().unwrap();
                let am = HostMat::new_ro(&a, na, na, na, T, MatId::A);
                let cm = HostMat::new(&mut b, m, n, m, T, MatId::C);
                let (cfg, arena) = cfg(16);
                run_real(&cfg, &ts, Mats { a: &am, b: None, c: &cm }, 2, arena).unwrap();
                hostblas::trmm_ref(side, uplo, ta, Diag::NonUnit, m, n, 1.4, &a, na, &mut want, m);
                assert!(
                    max_diff(&b, &want) < 1e-9,
                    "trmm {side:?} {uplo:?} {ta:?}: {}",
                    max_diff(&b, &want)
                );

                // TRSM
                let mut b2 = rand_mat(&mut p, m, n);
                let mut want2 = b2.clone();
                let ts2 = taskize_trsm(&d);
                ts2.validate().unwrap();
                let cm2 = HostMat::new(&mut b2, m, n, m, T, MatId::C);
                run_real(&cfg, &ts2, Mats { a: &am, b: None, c: &cm2 }, 2, arena).unwrap();
                hostblas::trsm_ref(side, uplo, ta, Diag::NonUnit, m, n, 1.4, &a, na, &mut want2, m);
                assert!(
                    max_diff(&b2, &want2) < 1e-9,
                    "trsm {side:?} {uplo:?} {ta:?}: {}",
                    max_diff(&b2, &want2)
                );
            }
        }
    }
}

#[test]
fn cache_pressure_still_correct() {
    // Arena of only 9 tiles: constant eviction, every path through the
    // ALRU doom/release machinery gets exercised.
    let (m, n, k) = (160, 160, 160);
    let mut p = Prng::new(6);
    let a = rand_mat(&mut p, m, k);
    let b = rand_mat(&mut p, k, n);
    let mut c = rand_mat(&mut p, m, n);
    let mut want = c.clone();

    let d = GemmDesc { ta: Trans::No, tb: Trans::No, m, n, k, alpha: 1.0, beta: 1.0, t: T };
    let ts = taskize_gemm(&d);
    let am = HostMat::new_ro(&a, m, k, m, T, MatId::A);
    let bm = HostMat::new_ro(&b, k, n, k, T, MatId::B);
    let cm = HostMat::new(&mut c, m, n, m, T, MatId::C);
    let (cfg, arena) = cfg(9);
    let rep = run_real(&cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, 3, arena).unwrap();
    // eviction must actually have happened for this test to mean anything
    assert!(rep.cache_delta.iter().any(|s| s.evictions > 0), "{:?}", rep.cache_delta);

    hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 1.0, &mut want, m);
    assert!(max_diff(&c, &want) < 1e-10);
}

#[test]
fn single_device_and_many_devices_agree() {
    let (m, n, k) = (128, 96, 64);
    let mut p = Prng::new(7);
    let a = rand_mat(&mut p, m, k);
    let b = rand_mat(&mut p, k, n);
    let c0 = rand_mat(&mut p, m, n);

    let d = GemmDesc { ta: Trans::No, tb: Trans::No, m, n, k, alpha: 2.0, beta: -1.0, t: T };
    let mut results = Vec::new();
    for n_dev in [1, 2, 4] {
        let mut c = c0.clone();
        let ts = taskize_gemm(&d);
        let am = HostMat::new_ro(&a, m, k, m, T, MatId::A);
        let bm = HostMat::new_ro(&b, k, n, k, T, MatId::B);
        let cm = HostMat::new(&mut c, m, n, m, T, MatId::C);
        let (cfg, arena) = cfg(16);
        let rep = run_real(&cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, n_dev, arena).unwrap();
        assert_eq!(rep.tasks_per_device.iter().sum::<usize>(), ts.tasks.len());
        results.push(c);
    }
    assert_eq!(results[0], results[1], "1 vs 2 devices");
    assert_eq!(results[0], results[2], "1 vs 4 devices");
}

#[test]
fn pjrt_backend_end_to_end() {
    // The paper-architecture path: tiles through AOT Pallas artifacts.
    let (m, n, k) = (96, 64, 64);
    let mut p = Prng::new(8);
    let a = rand_mat(&mut p, m, k);
    let b = rand_mat(&mut p, k, n);
    let mut c = rand_mat(&mut p, m, n);
    let mut want = c.clone();

    let d = GemmDesc { ta: Trans::No, tb: Trans::No, m, n, k, alpha: 1.5, beta: 0.5, t: T };
    let ts = taskize_gemm(&d);
    let am = HostMat::new_ro(&a, m, k, m, T, MatId::A);
    let bm = HostMat::new_ro(&b, k, n, k, T, MatId::B);
    let cm = HostMat::new(&mut c, m, n, m, T, MatId::C);
    let mut cfg = RunConfig { t: 64, backend: Backend::Pjrt, ..Default::default() };
    cfg.rs_capacity = 4;
    run_real(&cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, 2, 16 * 64 * 64 * 8).unwrap();

    hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.5, &a, m, &b, k, 0.5, &mut want, m);
    assert!(max_diff(&c, &want) < 1e-9, "pjrt path diff {}", max_diff(&c, &want));
}

#[test]
fn stealing_can_be_disabled() {
    let (m, n, k) = (96, 96, 32);
    let mut p = Prng::new(9);
    let a = rand_mat(&mut p, m, k);
    let b = rand_mat(&mut p, k, n);
    let mut c = rand_mat(&mut p, m, n);
    let mut want = c.clone();
    let d = GemmDesc { ta: Trans::No, tb: Trans::No, m, n, k, alpha: 1.0, beta: 0.0, t: T };
    let ts = taskize_gemm(&d);
    let am = HostMat::new_ro(&a, m, k, m, T, MatId::A);
    let bm = HostMat::new_ro(&b, k, n, k, T, MatId::B);
    let cm = HostMat::new(&mut c, m, n, m, T, MatId::C);
    let mut cfg = RunConfig { t: T, ..Default::default() };
    cfg.work_stealing = false;
    let rep = run_real(&cfg, &ts, Mats { a: &am, b: Some(&bm), c: &cm }, 2, 16 * T * T * 8).unwrap();
    assert!(rep.steals.iter().all(|&s| s == 0));
    hostblas::gemm_blocked(Trans::No, Trans::No, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut want, m);
    assert!(max_diff(&c, &want) < 1e-10);
}
