//! Property tests: every packed macro-kernel against its `*_ref`
//! oracle, across the full flag cross-product (`Trans`/`Side`/`Uplo`/
//! `Diag`), edge sizes around the block boundaries (m,n,k ∈ {0, 1,
//! T−1, T, T+1, …}), and the alpha=0 / beta=0 special cases.
//!
//! Block sizes are deliberately tiny (and non-dividing) so every edge
//! path — partial MR/NR micro-tiles, partial MC/NC/KC blocks, partial
//! diagonal blocks — executes many times within fast test sizes.
//!
//! Symmetric/triangular operands carry NaN in their *unstored* triangle
//! and C carries NaN in its *unwritten* triangle, proving the packed
//! kernels honour the same never-read/never-write contracts as the
//! oracles.

use blasx::api::types::{Diag, Side, Trans, Uplo};
use blasx::hostblas::sy::{syr2k_packed_nb, syrk_packed_nb};
use blasx::hostblas::tri::{trmm_packed_nb, trsm_packed_nb};
use blasx::hostblas::{
    gemm_packed_with, gemm_ref, symm_packed, symm_ref, syr2k_ref, syrk_ref, trmm_ref, trsm_ref,
    BlockDims,
};
use blasx::util::prng::Prng;

const TRANS: [Trans; 2] = [Trans::No, Trans::Yes];
const UPLOS: [Uplo; 2] = [Uplo::Upper, Uplo::Lower];
const SIDES: [Side; 2] = [Side::Left, Side::Right];
const DIAGS: [Diag; 2] = [Diag::NonUnit, Diag::Unit];

/// Edge sizes around the test block boundary T=8 (0, 1, T−1, T, T+1,
/// and a multi-block size that doesn't divide).
const EDGE: [usize; 6] = [0, 1, 7, 8, 9, 25];
const NB: usize = 8;

fn rand_mat(rng: &mut Prng, rows: usize, cols: usize, ld: usize) -> Vec<f64> {
    let mut v = vec![0.0; (ld * cols).max(1)];
    for c in 0..cols {
        for r in 0..rows {
            v[c * ld + r] = rng.range_f64(-1.0, 1.0);
        }
    }
    v
}

/// NaN-aware closeness: NaN must match NaN (proves untouched extents).
fn close(a: &[f64], b: &[f64], tol: f64) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            (x.is_nan() && y.is_nan()) || (x - y).abs() <= tol * x.abs().max(y.abs()).max(1.0)
        })
}

fn in_tri(uplo: Uplo, r: usize, c: usize) -> bool {
    match uplo {
        Uplo::Upper => r <= c,
        Uplo::Lower => r >= c,
    }
}

#[test]
fn gemm_packed_matches_ref_on_edge_grid() {
    let dims = BlockDims { mc: 8, nc: 8, kc: 8 };
    let mut rng = Prng::new(2024);
    for ta in TRANS {
        for tb in TRANS {
            for &m in &EDGE {
                for &n in &EDGE {
                    for &k in &EDGE {
                        let (ar, ac) = if ta == Trans::No { (m, k) } else { (k, m) };
                        let (br, bc) = if tb == Trans::No { (k, n) } else { (n, k) };
                        let (lda, ldb, ldc) = (ar + 2, br + 1, m + 3);
                        let a = rand_mat(&mut rng, ar, ac, lda);
                        let b = rand_mat(&mut rng, br, bc, ldb);
                        let c0 = rand_mat(&mut rng, m, n, ldc);
                        let mut want = c0.clone();
                        let mut got = c0.clone();
                        gemm_ref(ta, tb, m, n, k, 1.3, &a, lda, &b, ldb, -0.7, &mut want, ldc);
                        gemm_packed_with(
                            dims, ta, tb, m, n, k, 1.3, &a, lda, &b, ldb, -0.7, &mut got, ldc,
                        );
                        assert!(
                            close(&want, &got, 1e-10),
                            "gemm {ta:?}{tb:?} m={m} n={n} k={k}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn gemm_packed_alpha_beta_specials() {
    let dims = BlockDims { mc: 8, nc: 8, kc: 8 };
    let mut rng = Prng::new(99);
    let (m, n, k) = (9, 7, 25);
    let a = rand_mat(&mut rng, m, k, m);
    let b = rand_mat(&mut rng, k, n, k);
    for &(alpha, beta) in &[(0.0, 2.0), (1.0, 0.0), (0.0, 0.0), (1.0, 1.0)] {
        let c0 = rand_mat(&mut rng, m, n, m);
        let mut want = c0.clone();
        let mut got = c0.clone();
        gemm_ref(Trans::No, Trans::No, m, n, k, alpha, &a, m, &b, k, beta, &mut want, m);
        gemm_packed_with(
            dims, Trans::No, Trans::No, m, n, k, alpha, &a, m, &b, k, beta, &mut got, m,
        );
        assert!(close(&want, &got, 1e-10), "alpha={alpha} beta={beta}");
    }
}

/// C with NaN outside the stored triangle: packed kernels must leave
/// the NaNs exactly in place.
fn nan_masked_c(rng: &mut Prng, n: usize, ld: usize, uplo: Uplo) -> Vec<f64> {
    let mut c = vec![f64::NAN; (ld * n).max(1)];
    for j in 0..n {
        for i in 0..n {
            if in_tri(uplo, i, j) {
                c[j * ld + i] = rng.range_f64(-1.0, 1.0);
            }
        }
    }
    c
}

#[test]
fn syrk_packed_matches_ref_all_variants() {
    let mut rng = Prng::new(11);
    for uplo in UPLOS {
        for trans in TRANS {
            for &n in &EDGE {
                for &k in &[0usize, 1, 8, 17] {
                    let (ar, ac) = if trans == Trans::No { (n, k) } else { (k, n) };
                    let lda = ar + 1;
                    let a = rand_mat(&mut rng, ar, ac, lda);
                    let ldc = n + 2;
                    let c0 = nan_masked_c(&mut rng, n, ldc, uplo);
                    let mut want = c0.clone();
                    let mut got = c0.clone();
                    syrk_ref(uplo, trans, n, k, 1.2, &a, lda, 0.4, &mut want, ldc);
                    syrk_packed_nb(NB, uplo, trans, n, k, 1.2, &a, lda, 0.4, &mut got, ldc);
                    assert!(close(&want, &got, 1e-10), "syrk {uplo:?} {trans:?} n={n} k={k}");
                }
            }
        }
    }
    // alpha = 0 / beta = 0 specials keep triangle semantics
    let n = 17;
    let a = rand_mat(&mut rng, n, 9, n);
    for &(alpha, beta) in &[(0.0, 0.7), (1.1, 0.0), (0.0, 0.0)] {
        let c0 = nan_masked_c(&mut rng, n, n, Uplo::Lower);
        let mut want = c0.clone();
        let mut got = c0.clone();
        syrk_ref(Uplo::Lower, Trans::No, n, 9, alpha, &a, n, beta, &mut want, n);
        syrk_packed_nb(NB, Uplo::Lower, Trans::No, n, 9, alpha, &a, n, beta, &mut got, n);
        if beta == 0.0 {
            // ref multiplies beta in (NaN-preserving); packed follows
            // BLAS overwrite semantics — compare triangle content only
            for j in 0..n {
                for i in 0..n {
                    if in_tri(Uplo::Lower, i, j) {
                        let (w, g) = (want[j * n + i], got[j * n + i]);
                        assert!((w - g).abs() <= 1e-10 * w.abs().max(1.0));
                    } else {
                        assert!(got[j * n + i].is_nan());
                    }
                }
            }
        } else {
            assert!(close(&want, &got, 1e-10), "syrk specials a={alpha} b={beta}");
        }
    }
}

#[test]
fn syr2k_packed_matches_ref_all_variants() {
    let mut rng = Prng::new(13);
    for uplo in UPLOS {
        for trans in TRANS {
            for &n in &EDGE {
                for &k in &[0usize, 1, 9] {
                    let (ar, ac) = if trans == Trans::No { (n, k) } else { (k, n) };
                    let (lda, ldb) = (ar + 2, ar + 1);
                    let a = rand_mat(&mut rng, ar, ac, lda);
                    let b = rand_mat(&mut rng, ar, ac, ldb);
                    let ldc = n + 1;
                    let c0 = nan_masked_c(&mut rng, n, ldc, uplo);
                    let mut want = c0.clone();
                    let mut got = c0.clone();
                    syr2k_ref(uplo, trans, n, k, 0.9, &a, lda, &b, ldb, -0.3, &mut want, ldc);
                    syr2k_packed_nb(NB, uplo, trans, n, k, 0.9, &a, lda, &b, ldb, -0.3, &mut got, ldc);
                    assert!(close(&want, &got, 1e-10), "syr2k {uplo:?} {trans:?} n={n} k={k}");
                }
            }
        }
    }
}

/// Symmetric operand stored triangle-only, NaN elsewhere.
fn rand_sym(rng: &mut Prng, n: usize, ld: usize, uplo: Uplo) -> Vec<f64> {
    let mut a = vec![f64::NAN; (ld * n).max(1)];
    for c in 0..n {
        for r in 0..n {
            if in_tri(uplo, r, c) {
                a[c * ld + r] = rng.range_f64(-1.0, 1.0);
            }
        }
    }
    a
}

#[test]
fn symm_packed_matches_ref_all_variants() {
    let mut rng = Prng::new(19);
    for side in SIDES {
        for uplo in UPLOS {
            for &m in &EDGE {
                for &n in &EDGE {
                    let na = if side == Side::Left { m } else { n };
                    let lda = na + 1;
                    let a = rand_sym(&mut rng, na, lda, uplo);
                    let b = rand_mat(&mut rng, m, n, m + 2);
                    let c0 = rand_mat(&mut rng, m, n, m + 1);
                    let mut want = c0.clone();
                    let mut got = c0.clone();
                    symm_ref(side, uplo, m, n, 1.1, &a, lda, &b, m + 2, 0.4, &mut want, m + 1);
                    symm_packed(side, uplo, m, n, 1.1, &a, lda, &b, m + 2, 0.4, &mut got, m + 1);
                    assert!(close(&want, &got, 1e-10), "symm {side:?} {uplo:?} m={m} n={n}");
                    assert!(
                        m == 0 || n == 0 || !got.iter().any(|x| x.is_nan()),
                        "NaN leaked from unstored triangle"
                    );
                }
            }
        }
    }
}

/// Triangular operand: stored triangle with a dominant diagonal, NaN
/// in the never-read half.
fn rand_tri(rng: &mut Prng, n: usize, ld: usize, uplo: Uplo) -> Vec<f64> {
    let mut a = vec![f64::NAN; (ld * n).max(1)];
    for c in 0..n {
        for r in 0..n {
            if in_tri(uplo, r, c) {
                a[c * ld + r] = if r == c {
                    3.0 + rng.next_f64()
                } else {
                    rng.range_f64(-0.5, 0.5)
                };
            }
        }
    }
    a
}

#[test]
fn trmm_packed_matches_ref_all_variants() {
    let mut rng = Prng::new(101);
    for side in SIDES {
        for uplo in UPLOS {
            for ta in TRANS {
                for diag in DIAGS {
                    for &m in &EDGE {
                        for &n in &[0usize, 1, 8, 17] {
                            let na = if side == Side::Left { m } else { n };
                            let lda = na + 1;
                            let a = rand_tri(&mut rng, na, lda, uplo);
                            let b0 = rand_mat(&mut rng, m, n, m + 2);
                            let mut want = b0.clone();
                            let mut got = b0.clone();
                            trmm_ref(side, uplo, ta, diag, m, n, 1.5, &a, lda, &mut want, m + 2);
                            trmm_packed_nb(
                                NB, side, uplo, ta, diag, m, n, 1.5, &a, lda, &mut got, m + 2,
                            );
                            assert!(
                                close(&want, &got, 1e-10),
                                "trmm {side:?} {uplo:?} {ta:?} {diag:?} m={m} n={n}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn trsm_packed_matches_ref_all_variants() {
    let mut rng = Prng::new(202);
    for side in SIDES {
        for uplo in UPLOS {
            for ta in TRANS {
                for diag in DIAGS {
                    for &m in &EDGE {
                        for &n in &[0usize, 1, 8, 17] {
                            let na = if side == Side::Left { m } else { n };
                            let lda = na + 1;
                            let a = rand_tri(&mut rng, na, lda, uplo);
                            let b0 = rand_mat(&mut rng, m, n, m + 2);
                            let mut want = b0.clone();
                            let mut got = b0.clone();
                            trsm_ref(side, uplo, ta, diag, m, n, 1.4, &a, lda, &mut want, m + 2);
                            trsm_packed_nb(
                                NB, side, uplo, ta, diag, m, n, 1.4, &a, lda, &mut got, m + 2,
                            );
                            assert!(
                                close(&want, &got, 1e-8),
                                "trsm {side:?} {uplo:?} {ta:?} {diag:?} m={m} n={n}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn trsm_packed_alpha_zero_zeroes_rhs() {
    let mut rng = Prng::new(7);
    let (m, n) = (9, 5);
    let a = rand_tri(&mut rng, m, m, Uplo::Upper);
    let mut b = rand_mat(&mut rng, m, n, m);
    trsm_packed_nb(NB, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 0.0, &a, m, &mut b, m);
    assert!(b.iter().all(|&x| x == 0.0));
}

#[test]
fn packed_f32_spot_checks() {
    // f32 exercises the MR=16 micro-kernel specialization.
    let mut rng = Prng::new(33);
    let (m, n, k) = (37, 29, 41);
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; k * n];
    let mut c0 = vec![0.0f32; m * n];
    for x in a.iter_mut() {
        *x = rng.range_f64(-1.0, 1.0) as f32;
    }
    for x in b.iter_mut() {
        *x = rng.range_f64(-1.0, 1.0) as f32;
    }
    for x in c0.iter_mut() {
        *x = rng.range_f64(-1.0, 1.0) as f32;
    }
    let mut want = c0.clone();
    let mut got = c0.clone();
    gemm_ref(Trans::No, Trans::Yes, m, n, k, 1.25f32, &a, m, &b, n, -0.5f32, &mut want, m);
    let dims = BlockDims { mc: 16, nc: 12, kc: 9 };
    gemm_packed_with(dims, Trans::No, Trans::Yes, m, n, k, 1.25f32, &a, m, &b, n, -0.5f32, &mut got, m);
    for (w, g) in want.iter().zip(&got) {
        assert!((w - g).abs() <= 1e-3 * w.abs().max(1.0), "f32 gemm {w} vs {g}");
    }
    // f32 trsm through the packed solve
    let mut tri = vec![f32::NAN; m * m];
    for c in 0..m {
        for r in 0..=c {
            tri[c * m + r] =
                if r == c { 3.0 + rng.next_f64() as f32 } else { rng.range_f64(-0.4, 0.4) as f32 };
        }
    }
    let b0: Vec<f32> = (0..m * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let mut want = b0.clone();
    let mut got = b0.clone();
    trsm_ref(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0f32, &tri, m, &mut want, m);
    trsm_packed_nb(NB, Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, m, n, 1.0f32, &tri, m, &mut got, m);
    for (w, g) in want.iter().zip(&got) {
        assert!((w - g).abs() <= 1e-2 * w.abs().max(1.0), "f32 trsm {w} vs {g}");
    }
}
