//! Offline stub of the `xla` PJRT bindings.
//!
//! The real BLASX build links the xla-rs bindings and executes AOT HLO
//! artifacts through a PJRT CPU client. This container has no XLA
//! runtime, so this stub provides the exact API surface the crate
//! compiles against (`PjRtClient`, `HloModuleProto`, `XlaComputation`,
//! `PjRtLoadedExecutable`, `Literal`, `ElementType`) and fails at
//! *runtime*, at the earliest possible point (`PjRtClient::cpu`), with a
//! descriptive error. The coordinator's default `Backend::Hostblas`
//! path never touches this crate; only `Backend::Pjrt` callers see the
//! error, which they surface as `blasx::Error::Runtime`.
//!
//! Keeping the stub a separate path dependency (rather than a feature
//! gate inside blasx) means swapping the real bindings back in is a
//! one-line Cargo.toml change with zero source edits.

use std::fmt;

/// Error type mirroring `xla::Error`: carries a message, nothing more.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT runtime unavailable (offline stub build; \
         use the hostblas backend or link the real xla bindings)"
    ))
}

/// Element dtypes the tile programs use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    F64,
}

/// Marker trait for element types `Literal::copy_raw_to` accepts.
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}

/// Host-side tensor value (stub: never constructed successfully).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn copy_raw_to<T: ArrayElement>(&self, _dst: &mut [T]) -> Result<()> {
        Err(unavailable("Literal::copy_raw_to"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: construction itself reports the missing runtime,
/// so nothing downstream ever runs).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_a_readable_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline stub"), "{err}");
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F64, &[2, 2], &[0; 32])
            .is_err());
    }
}
