/* blasx.h — C API of libblasx v0.2.0 (generated: `blasx header`).
 *
 * BLASX (Wang et al. 2015) reproduction: a locality-aware multi-device
 * L3 BLAS runtime behind the standard CBLAS calling convention.
 *
 * Blocking calls (cblas_*) and asynchronous jobs (blasx_*_async) both
 * execute on one process-wide resident runtime: calls from different
 * threads are admitted as concurrent jobs, operand ranges that alias
 * are ordered by admission (results match the serial call sequence
 * bit-for-bit), disjoint calls overlap across the devices.
 *
 * CONTRACTS
 *  - Async liveness: buffers passed to blasx_*_async must stay valid
 *    until blasx_wait() returns for that job. One wait per handle;
 *    the wait frees the handle.
 *  - Host invalidation: the runtime caches tiles across calls, keyed
 *    by host address. If you mutate (or free and re-allocate) an
 *    INPUT buffer between calls, declare it first:
 *        blasx_invalidate_host(ptr, bytes);
 *    Output buffers never need this (each call re-epochs them).
 *    Setting BLASX_PERSISTENT=0 in the environment disables the
 *    resident runtime entirely (cold caches per call, nothing to
 *    declare; blasx_*_async then fails).
 *  - Environment (read once, at first call): BLASX_DEVICES,
 *    BLASX_TILE, BLASX_ARENA_MB, BLASX_KERNEL_THREADS,
 *    BLASX_PERSISTENT, BLASX_FAULTS (fault-injection schedule),
 *    BLASX_PROFILE (path to a `blasx tune` dispatch profile: per-shape
 *    tile size / kernel fan-out / host-vs-device placement; unreadable
 *    profiles are reported on stderr and ignored), BLASX_MT_CUTOFF
 *    (serial/fork flop cutoff of the multithreaded host kernel),
 *    BLASX_PREFETCH_DEPTH (lookahead tiles each device worker stages
 *    ahead of demand; 0/unset = off — results are bit-identical
 *    either way), BLASX_TELEMETRY_MS (background gauge-sampler period, ms; 0/unset
 *    = off: no thread, no allocation), BLASX_FLIGHT_DIR (arms the
 *    flight recorder's automatic incident dumps), BLASX_LOG
 *    (diagnostic verbosity: off|error|warn|info|debug|trace).
 *    Alternatively call blasx_init() with an explicit configuration
 *    BEFORE any other BLASX entry.
 */
#ifndef BLASX_H
#define BLASX_H

#include <stddef.h>
#include <stdint.h>

#ifdef __cplusplus
extern "C" {
#endif

/* ---- CBLAS enums (standard values) -------------------------------- */

typedef enum { CblasRowMajor = 101, CblasColMajor = 102 } CBLAS_ORDER;
typedef enum { CblasNoTrans = 111, CblasTrans = 112, CblasConjTrans = 113 } CBLAS_TRANSPOSE;
typedef enum { CblasUpper = 121, CblasLower = 122 } CBLAS_UPLO;
typedef enum { CblasNonUnit = 131, CblasUnit = 132 } CBLAS_DIAG;
typedef enum { CblasLeft = 141, CblasRight = 142 } CBLAS_SIDE;

/* ---- status codes (blasx_wait / blasx_last_error) ------------------ */

#define BLASX_OK            0  /* success                              */
#define BLASX_ERR_PARAM     1  /* illegal argument (xerbla-style)      */
#define BLASX_ERR_CONFIG    2  /* runtime misconfigured                */
#define BLASX_ERR_RUNTIME   3  /* kernel/artifact/I-O failure          */
#define BLASX_ERR_OOM       4  /* device arena exhausted               */
#define BLASX_ERR_INTERNAL  5  /* invariant violation / contained panic */
#define BLASX_ERR_DEGRADED  6  /* device lost; recovery exhausted      */
#define BLASX_ERR_DEADLINE  7  /* job overran its deadline, reaped     */
#define BLASX_ERR_CANCELLED 8  /* job cancelled via blasx_job_cancel   */
#define BLASX_ERR_BACKPRESSURE 9 /* admission refused: queue/quota full;
                                  * nothing enqueued — retry later     */

/* ---- initialization (optional) ------------------------------------- */

/* Explicit configuration — the programmatic twin of the BLASX_* env
 * knobs. Zero-initialize, then set the fields of interest: every
 * numeric field treats <= 0 (0 for deadline_ms) as "use the default". */
typedef struct blasx_config {
    int devices;            /* devices to run on            (<=0: default) */
    int tile;               /* square tile edge             (<=0: default) */
    int arena_mb;           /* per-device arena, MiB        (<=0: default) */
    int kernel_threads;     /* kernel threads per device    (<=0: default) */
    int one_shot;           /* nonzero: no resident runtime (async fails)  */
    uint64_t deadline_ms;   /* per-job deadline             (0: none)      */
    int max_inflight;       /* admission-queue capacity     (<=0: default) */
    int tenant_quota;       /* per-tenant in-flight quota   (<=0: default) */
    int prefetch;           /* lookahead prefetch depth, tiles staged
                             * ahead of demand per device worker
                             * (<=0: BLASX_PREFETCH_DEPTH, else off)       */
    const char *faults;     /* fault schedule, BLASX_FAULTS grammar
                             * (NULL/empty: none), e.g.
                             * "kill@dev1:op40; h2d@dev0:op5x2; seed=7"    */
    const char *profile;    /* dispatch-profile path (`blasx tune` JSON;
                             * NULL/empty: fixed tile size, no per-shape
                             * dispatch). Unlike BLASX_PROFILE, a bad
                             * path here fails the init loudly.          */
} blasx_config_t;

/* Configure the process-global runtime. Must be the FIRST BLASX call:
 * once any other entry has booted the env-driven defaults, this
 * returns BLASX_ERR_CONFIG. A malformed faults string returns
 * BLASX_ERR_PARAM and configures nothing. cfg may be NULL (claim the
 * defaults). The struct is copied; faults need not outlive the call. */
int blasx_init(const blasx_config_t *cfg);

/* ---- blocking CBLAS-compatible entry points ------------------------ */
/* Errors are reported CBLAS-style: a diagnostic on stderr, the call
 * returns without computing; blasx_last_error() retrieves the text.  */

void cblas_sgemm(int order, int transa, int transb, int m, int n, int k,
                 float alpha, const float *a, int lda,
                 const float *b, int ldb,
                 float beta, float *c, int ldc);
void cblas_dgemm(int order, int transa, int transb, int m, int n, int k,
                 double alpha, const double *a, int lda,
                 const double *b, int ldb,
                 double beta, double *c, int ldc);

void cblas_ssyrk(int order, int uplo, int trans, int n, int k,
                 float alpha, const float *a, int lda,
                 float beta, float *c, int ldc);
void cblas_dsyrk(int order, int uplo, int trans, int n, int k,
                 double alpha, const double *a, int lda,
                 double beta, double *c, int ldc);

void cblas_ssyr2k(int order, int uplo, int trans, int n, int k,
                  float alpha, const float *a, int lda,
                  const float *b, int ldb,
                  float beta, float *c, int ldc);
void cblas_dsyr2k(int order, int uplo, int trans, int n, int k,
                  double alpha, const double *a, int lda,
                  const double *b, int ldb,
                  double beta, double *c, int ldc);

void cblas_ssymm(int order, int side, int uplo, int m, int n,
                 float alpha, const float *a, int lda,
                 const float *b, int ldb,
                 float beta, float *c, int ldc);
void cblas_dsymm(int order, int side, int uplo, int m, int n,
                 double alpha, const double *a, int lda,
                 const double *b, int ldb,
                 double beta, double *c, int ldc);

void cblas_strmm(int order, int side, int uplo, int transa, int diag,
                 int m, int n, float alpha, const float *a, int lda,
                 float *b, int ldb);
void cblas_dtrmm(int order, int side, int uplo, int transa, int diag,
                 int m, int n, double alpha, const double *a, int lda,
                 double *b, int ldb);

void cblas_strsm(int order, int side, int uplo, int transa, int diag,
                 int m, int n, float alpha, const float *a, int lda,
                 float *b, int ldb);
void cblas_dtrsm(int order, int side, int uplo, int transa, int diag,
                 int m, int n, double alpha, const double *a, int lda,
                 double *b, int ldb);

/* ---- asynchronous jobs --------------------------------------------- */

/* Opaque in-flight job. NULL return = submission failed (see
 * blasx_last_error). */
typedef struct blasx_job blasx_job_t;

blasx_job_t *blasx_sgemm_async(int order, int transa, int transb,
                               int m, int n, int k,
                               float alpha, const float *a, int lda,
                               const float *b, int ldb,
                               float beta, float *c, int ldc);
blasx_job_t *blasx_dgemm_async(int order, int transa, int transb,
                               int m, int n, int k,
                               double alpha, const double *a, int lda,
                               const double *b, int ldb,
                               double beta, double *c, int ldc);
blasx_job_t *blasx_strsm_async(int order, int side, int uplo,
                               int transa, int diag, int m, int n,
                               float alpha, const float *a, int lda,
                               float *b, int ldb);
blasx_job_t *blasx_dtrsm_async(int order, int side, int uplo,
                               int transa, int diag, int m, int n,
                               double alpha, const double *a, int lda,
                               double *b, int ldb);

/* Park until the job retires; frees the handle; returns a BLASX_*
 * status. Outputs are fully written back when this returns BLASX_OK. */
int blasx_wait(blasx_job_t *job);

/* 1 = retired (wait will not block), 0 = in flight, -1 = NULL. Does
 * not free the handle. */
int blasx_job_done(const blasx_job_t *job);

/* Request cooperative cancellation: the job aborts with
 * BLASX_ERR_CANCELLED at its next round boundary (outputs are never
 * torn mid-tile) — unless it finished first. Idempotent; does not free
 * the handle, so blasx_wait must still run and returns the verdict. */
int blasx_job_cancel(const blasx_job_t *job);

/* Observability counters of one job — the numbers blasx_wait discards
 * with its report. Counters are monotone while the job runs. */
typedef struct blasx_stats {
    uint64_t tasks;        /* scheduler tasks executed so far          */
    uint64_t host_reads_a; /* host->device tile reads of operand A     */
    uint64_t host_reads_b; /* host->device tile reads of operand B     */
    uint64_t host_reads_c; /* host->device tile reads of operand C     */
    uint64_t peer_copies;  /* device->device (peer) tile copies        */
    uint64_t l1_hits;      /* tile-cache hits (no bytes moved)         */
    uint64_t steals;       /* tasks obtained by work stealing          */
    uint64_t retried;      /* ops retried after transient faults       */
    uint64_t degraded;     /* operands served via host OOM fallback    */
    uint64_t migrated;     /* tasks migrated off lost devices          */
    uint64_t prefetch_hits;   /* acquires served by a prefetched tile  */
    uint64_t prefetch_wasted; /* prefetched tiles dropped unconsumed   */
} blasx_stats_t;

/* Snapshot the job's live counters into *out. Non-blocking; valid
 * while the job is in flight; does not free the handle. Returns
 * BLASX_OK, or BLASX_ERR_INTERNAL on a NULL argument. */
int blasx_job_stats(const blasx_job_t *job, blasx_stats_t *out);

/* ---- runtime control ----------------------------------------------- */

void blasx_invalidate_host(const void *ptr, size_t bytes);
void blasx_shutdown(void);

/* Copy this thread's last error (NUL-terminated) into buf; returns the
 * full message length (0 = no error recorded). */
size_t blasx_last_error(char *buf, size_t cap);

/* ---- live telemetry & flight recorder ------------------------------ */

/* Render the live runtime gauges (arena bytes, cache hit rates, queue
 * depth, per-tenant in-flight, worker busy fractions) in Prometheus
 * text exposition format — the same body `blasx serve
 * --telemetry-addr` serves at /metrics. Copies the NUL-terminated text
 * into buf and returns the full length (excluding the NUL); call with
 * NULL/0 to size a buffer. A cold library reports `blasx_up 0` without
 * booting the runtime. */
size_t blasx_telemetry_text(char *buf, size_t cap);

/* Dump the always-on flight recorder (the black box: the last ~256
 * admissions/faults/migrations per device) into directory `dir` as an
 * incident report — structured JSON plus a Chrome trace. The same
 * dump fires automatically on a device loss, deadline reap, or worker
 * panic when BLASX_FLIGHT_DIR is set. Returns BLASX_OK,
 * BLASX_ERR_CONFIG when the runtime never booted, or
 * BLASX_ERR_INTERNAL on I/O failure (see blasx_last_error). */
int blasx_flight_dump(const char *dir);

/* Static identification string, e.g. "blasx 0.2.0". */
const char *blasx_version(void);

#ifdef __cplusplus
}
#endif

#endif /* BLASX_H */
