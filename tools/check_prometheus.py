#!/usr/bin/env python3
"""Scrape gate for the live telemetry endpoint (stdlib only).

Polls `http://ADDR/metrics` until the endpoint answers (the serve run
may still be booting), then validates the body as Prometheus text
exposition format 0.0.4:

- every sample line parses as `name[{labels}] value` with a float value;
- every sampled family is announced by `# HELP` and `# TYPE` lines;
- the required families for a live blasx runtime are present with at
  least one sample: blasx_up (== 1), blasx_device_up,
  blasx_arena_bytes_in_use, blasx_cache_hit_rate, blasx_queue_depth,
  blasx_jobs_retired_total, blasx_worker_busy_fraction;
- gauge ranges hold (hit rate and busy fraction in [0, 1]).

Then checks `/healthz`: 200/`ok` for a healthy fleet, or — with
`--expect-unhealthy` — 503 naming at least one dead device.

Usage:
    python3 tools/check_prometheus.py [--addr 127.0.0.1:9464]
        [--timeout 30] [--expect-unhealthy]

Exits non-zero on the first violation.
"""

import argparse
import re
import sys
import time
import urllib.error
import urllib.request

REQUIRED_FAMILIES = (
    "blasx_up",
    "blasx_device_up",
    "blasx_arena_bytes_in_use",
    "blasx_cache_hit_rate",
    "blasx_queue_depth",
    "blasx_jobs_retired_total",
    "blasx_worker_busy_fraction",
)

SAMPLE_RE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")


def fetch(url, timeout):
    """GET url, returning (status, body) without raising on HTTP errors."""
    try:
        with urllib.request.urlopen(url, timeout=timeout) as resp:
            return resp.status, resp.read().decode("utf-8", "replace")
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode("utf-8", "replace")


def poll(url, deadline):
    """Retry until the endpoint answers or the deadline passes."""
    last = None
    while time.monotonic() < deadline:
        try:
            return fetch(url, timeout=2)
        except (urllib.error.URLError, OSError) as e:
            last = e
            time.sleep(0.2)
    sys.exit(f"endpoint never answered: {url} ({last})")


def parse_exposition(body):
    """Return (samples, families): samples as (name, labels, value),
    families as the set announced by # TYPE lines."""
    samples, families = [], set()
    for lineno, line in enumerate(body.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            m = re.match(r"^# (HELP|TYPE) (\S+)", line)
            if not m:
                sys.exit(f"line {lineno}: malformed comment line: {line!r}")
            if m.group(1) == "TYPE":
                families.add(m.group(2))
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            sys.exit(f"line {lineno}: unparseable sample line: {line!r}")
        name, labels, value = m.group(1), m.group(2) or "", m.group(3)
        try:
            value = float(value)
        except ValueError:
            sys.exit(f"line {lineno}: non-numeric value: {line!r}")
        samples.append((name, labels, value))
    return samples, families


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--addr", default="127.0.0.1:9464")
    ap.add_argument("--timeout", type=float, default=30.0)
    ap.add_argument(
        "--expect-unhealthy",
        action="store_true",
        help="require /healthz to report 503 with a dead device",
    )
    args = ap.parse_args()
    deadline = time.monotonic() + args.timeout

    status, body = poll(f"http://{args.addr}/metrics", deadline)
    if status != 200:
        sys.exit(f"/metrics returned {status}")
    samples, families = parse_exposition(body)
    if not samples:
        sys.exit("/metrics body has no samples")

    by_name = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
        if name not in families:
            sys.exit(f"sample {name} has no # TYPE announcement")

    for family in REQUIRED_FAMILIES:
        if family not in by_name:
            sys.exit(f"required family missing from scrape: {family}")
    up = by_name["blasx_up"][0][1]
    if up != 1.0:
        sys.exit(f"blasx_up is {up}, runtime not booted behind the endpoint")
    for labels, value in by_name["blasx_cache_hit_rate"]:
        if not (0.0 <= value <= 1.0):
            sys.exit(f"cache hit rate out of range: {labels} {value}")
    for labels, value in by_name["blasx_worker_busy_fraction"]:
        if not (0.0 <= value <= 1.0):
            sys.exit(f"busy fraction out of range: {labels} {value}")

    # The expected health state may lag the first scrape (a kill
    # schedule fires mid-run), so retry until the deadline.
    want = 503 if args.expect_unhealthy else 200
    while True:
        status, health = poll(f"http://{args.addr}/healthz", deadline)
        if status == want:
            break
        if time.monotonic() >= deadline:
            sys.exit(f"/healthz stuck at {status} ({health!r}), wanted {want}")
        time.sleep(0.3)
    if args.expect_unhealthy:
        if not re.search(r"\d", health):
            sys.exit(f"unhealthy /healthz names no device: {health!r}")
    elif health.strip() != "ok":
        sys.exit(f"healthy /healthz body is {health!r}, expected 'ok'")

    devices = len(by_name["blasx_device_up"])
    print(
        f"scrape ok: {len(samples)} samples across {len(families)} families, "
        f"{devices} device(s), healthz "
        + ("503 as expected" if args.expect_unhealthy else "ok")
    )


if __name__ == "__main__":
    main()
