#!/usr/bin/env python3
"""Schema gate for the BENCH_*.json artifacts (stdlib only).

Every bench in benches/ writes two copies of its result document: a
fresh `bench_out/BENCH_*.json` on each run and a committed repo-root
snapshot. This gate keeps both machine-consumable:

- every document must be an object with a string `bench` name and a
  `results` array;
- an EMPTY `results` array is legal only for a placeholder snapshot
  (authored without a Rust toolchain) and must carry a `note` saying
  how to regenerate — an empty array without one means the bench
  silently measured nothing;
- non-empty results are checked per bench: rows must be flat objects
  with the columns the analyses read, and the acceptance numbers ride
  along (the mixed-tile dispatch bench must show ZERO post-warmup host
  reads in the `mixed-tile warm` scenario — the PR-8 property that
  deleting the tile-size purge was sound).

The same gate validates flight-recorder incident reports (schema
`blasx-incident-v1`, written by the runtime's auto-dump on a device
kill / deadline reap / worker panic, or by `blasx_flight_dump`):

    python3 tools/check_bench_schema.py --incident incidents/*.json

Usage:
    python3 tools/check_bench_schema.py [BENCH_a.json ...]
    python3 tools/check_bench_schema.py --incident incident_*.json

With no arguments, checks every BENCH_*.json at the repo root.
Exits 1 on the first malformed document.
"""

import glob
import json
import os
import sys


def fail(path, msg):
    sys.exit(f"{path}: {msg}")


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def check_rows(path, rows, required, numeric):
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            fail(path, f"results[{i}] is not an object")
        for col in required:
            if col not in row:
                fail(path, f"results[{i}] lacks column {col!r}")
        for col in numeric:
            if col in row and not is_num(row[col]):
                fail(path, f"results[{i}].{col} is not a number: {row[col]!r}")


def check_dispatch(path, doc):
    rows = doc["results"]
    check_rows(
        path,
        rows,
        required=("scenario", "calls", "wall_ms", "calls_per_sec", "warm_host_reads"),
        numeric=("calls", "wall_ms", "calls_per_sec", "warm_host_reads"),
    )
    by_scenario = {r["scenario"]: r for r in rows}
    warm = by_scenario.get("mixed-tile warm")
    if warm is None:
        fail(path, "no 'mixed-tile warm' scenario row")
    if warm["warm_host_reads"] != 0:
        fail(
            path,
            "mixed-tile warm scenario re-read "
            f"{warm['warm_host_reads']} tiles from the host — alternating "
            "tile sizes must be transfer-free (per-geometry generations)",
        )
    probe = doc.get("overhead_probe") or {}
    if probe:
        for key in ("warm_call_ms_plain", "warm_call_ms_dispatched"):
            if not is_num(probe.get(key)):
                fail(path, f"overhead_probe.{key} missing or not a number")


def check_serve(path, doc):
    check_rows(
        path,
        doc["results"],
        required=("clients", "jobs", "wall_ms", "jobs_per_sec", "latency_p99_ms"),
        numeric=("clients", "jobs", "wall_ms", "jobs_per_sec", "latency_p99_ms"),
    )


def check_overlap(path, doc):
    rows = doc["results"]
    check_rows(
        path,
        rows,
        required=(
            "config", "phase", "wall_ms", "overlap_fraction",
            "prefetch_hits", "prefetch_wasted", "host_read_tiles",
        ),
        numeric=(
            "wall_ms", "overlap_fraction", "comm_s", "comm_hidden_s",
            "prefetch_hits", "prefetch_wasted", "host_read_tiles",
        ),
    )
    by_key = {(r["config"], r["phase"]): r for r in rows}
    cold_on = by_key.get(("prefetch-on", "cold"))
    if cold_on is None:
        fail(path, "no ('prefetch-on', 'cold') row")
    if not cold_on["overlap_fraction"] > 0:
        fail(
            path,
            "prefetch-on cold run hid no comm under compute "
            f"(overlap_fraction {cold_on['overlap_fraction']!r}) — the "
            "lookahead pipeline measured zero overlap",
        )
    warm_on = by_key.get(("prefetch-on", "warm"))
    if warm_on is None:
        fail(path, "no ('prefetch-on', 'warm') row")
    if warm_on["host_read_tiles"] != 0:
        fail(
            path,
            f"prefetch-on warm call read {warm_on['host_read_tiles']} "
            "tiles from the host — lookahead must never break residency",
        )
    probe = doc.get("lock_probe") or {}
    if probe:
        for key in ("off_max_ms", "on_max_ms"):
            if not is_num(probe.get(key)):
                fail(path, f"lock_probe.{key} missing or not a number")


def check_runtime(path, doc):
    check_rows(path, doc["results"], required=(), numeric=())
    if not doc.get("recorder_overhead"):
        fail(path, "call_overhead lost its recorder perf gate (recorder_overhead)")


# Extra per-bench validation once real numbers are present, keyed by
# the document's `bench` field. Benches absent here get the generic
# object/array checks only.
EXTRA = {
    "dispatch_mixed": check_dispatch,
    "serve_throughput": check_serve,
    "call_overhead": check_runtime,
    "transfer_overlap": check_overlap,
}


def check(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(path, f"unreadable: {e}")
    if not isinstance(doc, dict):
        fail(path, "top level is not an object")
    bench = doc.get("bench")
    if not isinstance(bench, str) or not bench:
        fail(path, "missing string `bench` name")
    results = doc.get("results")
    if not isinstance(results, list):
        fail(path, "missing `results` array")
    if not results:
        note = doc.get("note", "")
        if not isinstance(note, str) or "cargo bench" not in note:
            fail(
                path,
                "empty results without a regeneration note — "
                "the bench silently measured nothing",
            )
        print(f"{path}: placeholder ok ({bench}; schema-only)")
        return
    extra = EXTRA.get(bench)
    if extra:
        extra(path, doc)
    else:
        check_rows(path, results, required=(), numeric=())
    print(f"{path}: ok ({bench}, {len(results)} rows)")


EVENT_KINDS = {
    "admit", "reject", "retire", "fault", "migrate",
    "reap", "panic", "retry", "degrade",
}


def check_incident(path):
    """Validate one blasx-incident-v1 flight-recorder report."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(path, f"unreadable: {e}")
    if not isinstance(doc, dict):
        fail(path, "top level is not an object")
    if doc.get("schema") != "blasx-incident-v1":
        fail(path, f"unknown schema: {doc.get('schema')!r}")
    if not isinstance(doc.get("seq"), int) or doc["seq"] < 0:
        fail(path, "missing non-negative integer `seq`")
    if not isinstance(doc.get("reason"), str) or not doc["reason"]:
        fail(path, "missing string `reason`")
    if not is_num(doc.get("t_s")) or doc["t_s"] < 0:
        fail(path, "missing non-negative `t_s`")
    dead = doc.get("dead_devices")
    if not isinstance(dead, list) or any(
        not isinstance(d, int) or d < 0 for d in dead
    ):
        fail(path, "`dead_devices` must be a list of device indices")
    events = doc.get("events")
    if not isinstance(events, list):
        fail(path, "missing `events` array")
    counted = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            fail(path, f"events[{i}] is not an object")
        if e.get("kind") not in EVENT_KINDS:
            fail(path, f"events[{i}] has unknown kind {e.get('kind')!r}")
        for col in ("t_s", "dev", "job", "tenant", "amount"):
            if not is_num(e.get(col)):
                fail(path, f"events[{i}].{col} missing or not a number")
        if e["dev"] < -1:
            fail(path, f"events[{i}].dev out of range: {e['dev']}")
        counted[e["kind"]] = counted.get(e["kind"], 0) + 1
    counts = doc.get("event_counts")
    if not isinstance(counts, dict):
        fail(path, "missing `event_counts` object")
    if counts != counted:
        fail(path, f"event_counts {counts} disagree with events {counted}")
    print(
        f"{path}: incident ok (reason {doc['reason']!r}, "
        f"{len(events)} events, dead devices {dead})"
    )


def main():
    paths = sys.argv[1:]
    if paths and paths[0] == "--incident":
        paths = paths[1:]
        if not paths:
            sys.exit("--incident needs at least one report path")
        for path in paths:
            check_incident(path)
        return
    if not paths:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not paths:
        sys.exit("no BENCH_*.json found")
    for path in paths:
        check(path)


if __name__ == "__main__":
    main()
